"""AsyncSolveService: the framework-agnostic serving core (DESIGN.md §20/§21).

The paper's architecture *serves* imaging workloads; this module is the
traffic side of that claim.  One asyncio event loop owns all scheduling
state (no locks on the hot path); actual solves run on a small worker
executor so the loop stays responsive:

- **submit** — admission control first: a draining service, a full
  queue, or an open circuit breaker rejects with a *retriable* status
  (the client's signal to back off or go elsewhere), everything else is
  journaled and enqueued for coalescing.
- **micro-batch scheduler** — requests are grouped by a compatibility
  key (workload + config fingerprint + run-option fingerprint) and then
  offered to an incremental :class:`~repro.core.batching.OpenBucketPlanner`
  (same static-signature grouping and waste-budget rule as the offline
  ``solve_many`` planner).  The first request into an open bucket arms a
  deadline timer (``batch_window_s``, tightened toward the earliest
  member ``deadline_s``); the bucket dispatches when the window expires,
  when it reaches ``max_batch`` occupancy, or when a drain flushes it.
- **dispatch** — a closed bucket runs as ONE ``solve_many`` call (a
  single-member bucket takes the plain ``solve`` path) on the executor,
  with per-request ``RunOptions`` — including ``resilience=`` — passed
  straight through.  The driver's ``progress_fn`` chunk events are
  relayed onto the loop and fanned out per request; the relay's control
  *return* is how the service reaches INTO a running batch: expired or
  cancelled lanes are frozen at the next chunk boundary exactly like
  converged ones (§21), without perturbing sibling trajectories.
- **failure isolation** — a coalesced dispatch that fails as a unit
  (retry/rollback budget exhausted) is *quarantined*: every lane
  re-dispatches solo, so only the offending request fails (with the
  recovery ledger attached) while siblings complete with trajectory
  parity.  A hung dispatch is reaped by the watchdog after
  ``dispatch_timeout_s``.  Outcomes feed a per-workload circuit
  breaker (``serve.breaker``) that sheds load when a workload goes bad.
- **durability** — with ``journal_dir`` set, every admission, bucket
  assignment, and terminal state is logged to a crc-per-record WAL
  (``serve.journal``); a restarted service replays still-owed requests
  and re-dispatches journaled buckets in their original order, resuming
  from per-bucket checkpoints when ``checkpoint_dir`` has them.
- **drain** — stop admitting, *reject* still-queued requests with the
  retriable status, let in-flight batches finish.  ``close()`` drains
  and tears down the executor; ``abandon()`` is the simulated hard
  crash of the §21 kill/restart drill.

A request carrying ``chaos_spec`` (the §18 fault-injection drill)
always dispatches as its own singleton batch: chaos activation is
process-global, so an injected fault must never share a dispatch with
paying traffic.  Serving-layer chaos (``ServeConfig.chaos_spec``,
points ``serve_admit_drop`` / ``serve_bucket_poison`` /
``serve_crash``) instead lives on a service-owned counter state and
never touches the solve loop's global harness.
"""
from __future__ import annotations

import asyncio
import itertools
import time
import uuid
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import batching
from repro.core.problem import Solution, _as_problem, \
    _config_fingerprint, solve, solve_many
from repro.serve.breaker import CircuitBreaker
from repro.serve.metrics import Metrics

#: terminal request states — once here, a record never changes again
TERMINAL = ("done", "failed", "cancelled", "rejected")
#: every state a record can be in
STATES = ("queued", "running") + TERMINAL


@dataclass(frozen=True)
class ServeConfig:
    """Service-level knobs (per-request solver knobs ride each
    :class:`SolveRequest` instead).

    - ``max_queue`` — admission-control cap on queued+running requests;
      beyond it, submits are rejected retriable (closed-loop clients
      back off, the paper's Spark analogue would spill to another
      executor).
    - ``batch_window_s`` — coalescing deadline: how long the first
      request in an open bucket waits for compatible companions before
      the bucket dispatches anyway.  0 disables coalescing (every
      request dispatches solo — the serialized baseline of
      ``benchmarks/bench_serve``).  A member with a tight ``deadline_s``
      shortens the wait: the bucket dispatches with at least half the
      request's remaining budget left for the solve.
    - ``max_batch`` — occupancy that dispatches an open bucket early.
    - ``workers`` — executor threads running solves.  The default of 1
      serializes device work (one process-wide accelerator); >1 only
      helps when solves block on I/O or separate devices.
    - ``waste_budget`` — open-bucket padding budget (see
      ``core.batching``); serving defaults looser than ``solve_many``'s
      0.25 because coalescing wins usually beat padding waste.
    - ``quarantine`` — poison-bucket isolation (§21): re-dispatch the
      lanes of a failed coalesced bucket solo so only the offending
      request fails.  Off, a bucket failure fails every member (the
      pre-§21 behavior).
    - ``dispatch_timeout_s`` — hung-dispatch watchdog: an in-flight
      batch with no completion after this long is reaped (its requests
      fail, the breaker records the fault).  ``None`` disables.
    - ``breaker_*`` — per-workload circuit breaker (``serve.breaker``):
      sliding-window size, minimum samples before tripping, error-rate
      threshold, and open-state cooldown before the half-open probe.
    - ``journal_dir`` — crash-safe request journal (``serve.journal``):
      admissions/buckets/terminal states WAL'd here; a service started
      over an existing journal replays still-owed work.  ``None``
      disables durability.
    - ``checkpoint_dir`` / ``checkpoint_every`` — per-bucket
      checkpointing for coalesced dispatches (forwarded to
      ``solve_many``); with the journal this is what lets a restart
      *resume* an in-flight bucket instead of recomputing it.
    - ``chaos_spec`` — serving-layer chaos plan (§21 drills), same
      grammar as ``REPRO_CHAOS`` but only the ``serve_*`` points are
      consumed and the counter state is service-owned.
    """
    max_queue: int = 256
    batch_window_s: float = 0.05
    max_batch: int = 32
    workers: int = 1
    waste_budget: float = 0.5
    history_window: int = 2048
    quarantine: bool = True
    dispatch_timeout_s: Optional[float] = None
    breaker_window: int = 32
    breaker_min_samples: int = 8
    breaker_error_threshold: float = 0.5
    breaker_cooldown_s: float = 5.0
    journal_dir: Optional[str] = None
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0
    chaos_spec: Optional[str] = None


@dataclass(frozen=True)
class SolveRequest:
    """One client request: exactly the arguments of a ``solve()`` call.

    ``options`` holds run-control overrides (``max_iter``, ``tol``,
    ``chunk``, ``cost_every``, ``resilience=ResilienceConfig(...)``,
    ...); step wiring is always derived from the Problem declaration.
    ``chaos_spec`` arms the §18 fault-injection harness for this request
    only (dispatched solo, see module docstring).  ``deadline_s`` is a
    wall-clock budget from submission: a request still running past it
    is frozen at the next chunk boundary and fails with a deadline
    error (siblings in its bucket are unaffected).
    """
    problem: str
    inputs: Tuple[Any, ...]
    cfg: Any = None
    options: Dict[str, Any] = field(default_factory=dict)
    chaos_spec: Optional[str] = None
    deadline_s: Optional[float] = None


@dataclass
class RequestRecord:
    """Mutable server-side state of one request.

    Written by the service loop and (status/timestamps/result fields)
    by the executor worker running its batch; read by transports.
    ``retriable`` is only meaningful with status ``"rejected"``: the
    request never ran and can be resubmitted verbatim.  ``recovery``
    is the per-request §18 ledger (sliced from the bucket's shared
    report, or the solo re-run's after quarantine).
    """
    id: str
    request: SolveRequest
    status: str = "queued"
    retriable: bool = False
    error: Optional[str] = None
    solution: Optional[Solution] = None
    recovery: Optional[Any] = None
    submitted_at: float = 0.0
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    batch_size: int = 0
    bucket_key: Optional[str] = None
    replayed: bool = False
    quarantined: bool = False
    cancel_requested: bool = False
    events: List[dict] = field(default_factory=list)
    # loop-side plumbing (not part of the public record)
    done: asyncio.Event = field(default_factory=asyncio.Event, repr=False)
    _waiters: List[asyncio.Future] = field(default_factory=list,
                                           repr=False)
    _token: Optional[int] = field(default=None, repr=False)
    _open: Optional[batching.OpenBucket] = field(default=None, repr=False)
    _lane: Optional["_Lane"] = field(default=None, repr=False)
    # worker-side plumbing (§21): why this lane froze mid-flight, the
    # quarantine solo re-run's failure, and chaos-poisoned inputs
    _frozen_reason: Optional[str] = field(default=None, repr=False)
    _solo_error: Optional[BaseException] = field(default=None, repr=False)
    _inputs_override: Optional[Tuple[Any, ...]] = field(default=None,
                                                        repr=False)

    @property
    def latency_s(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at

    def public(self) -> dict:
        """JSON-ready status view (no arrays, no Solution)."""
        return {
            "id": self.id, "status": self.status,
            "retriable": self.retriable, "error": self.error,
            "problem": self.request.problem,
            "batch_size": self.batch_size,
            "bucket_key": self.bucket_key,
            "submitted_at": self.submitted_at,
            "started_at": self.started_at,
            "finished_at": self.finished_at,
            "latency_s": self.latency_s,
            "deadline_s": self.request.deadline_s,
            "replayed": self.replayed,
            "quarantined": self.quarantined,
            "n_events": len(self.events),
        }


class _Lane:
    """All open buckets of one compatibility key (workload + config +
    run options): requests only coalesce within a lane."""

    def __init__(self, key: str, problem, axes: batching.BatchAxes,
                 planner: batching.OpenBucketPlanner):
        self.key = key
        self.problem = problem          # prototype Problem instance
        self.axes = axes
        self.planner = planner
        # open bucket -> [bucket, records in admission order, timer]
        # (a list: the timer slot is re-armed when a tight-deadline
        # member joins)
        self.pending: Dict[int, List] = {}


class RequestRejected(RuntimeError):
    """Raised by :meth:`AsyncSolveService.submit` at admission time.
    ``retriable`` mirrors the record's flag: the request never ran."""

    def __init__(self, msg: str, record: RequestRecord):
        super().__init__(msg)
        self.record = record
        self.retriable = record.retriable


class AsyncSolveService:
    """The asyncio serving core.  All public coroutines must run on the
    loop that called :meth:`start`; transports on other threads bridge
    via ``asyncio.run_coroutine_threadsafe`` (see ``serve.server``)."""

    def __init__(self, config: Optional[ServeConfig] = None, *,
                 mesh=None):
        self.cfg = config or ServeConfig()
        self.mesh = mesh
        self.metrics = Metrics(window=self.cfg.history_window)
        self.records: Dict[str, RequestRecord] = {}
        self._lanes: Dict[str, _Lane] = {}
        # fut id -> (future, records, started_at monotonic)
        self._inflight: Dict[int, Tuple[Any, List[RequestRecord],
                                        float]] = {}
        self._breakers: Dict[str, CircuitBreaker] = {}
        self._draining = False
        self._closed = False
        self._crashed = False
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._watchdog_task: Optional[asyncio.Task] = None
        self._executor = ThreadPoolExecutor(
            max_workers=max(int(self.cfg.workers), 1),
            thread_name_prefix="repro-serve")
        self._tokens = itertools.count()
        self._journal = None
        if self.cfg.journal_dir is not None:
            from repro.serve.journal import RequestJournal
            self._journal = RequestJournal(self.cfg.journal_dir)
        self._chaos = None
        if self.cfg.chaos_spec:
            from repro.resilience import chaos as _chaos_mod
            self._chaos = _chaos_mod._ChaosState(
                _chaos_mod.ChaosConfig.parse(self.cfg.chaos_spec))

    # ----------------------------------------------------------- setup
    async def start(self) -> "AsyncSolveService":
        self._loop = asyncio.get_running_loop()
        if self.cfg.dispatch_timeout_s:
            self._watchdog_task = self._loop.create_task(
                self._watchdog())
            self._watchdog_task.add_done_callback(self._task_exc)
        if self._journal is not None:
            self._replay_journal()
        return self

    @staticmethod
    def _task_exc(task: asyncio.Task) -> None:
        """Done-callback retrieving a background task's exception so it
        is never silently dropped (lint rule RPL901)."""
        if not task.cancelled() and task.exception() is not None:
            import traceback
            traceback.print_exception(task.exception())

    async def __aenter__(self) -> "AsyncSolveService":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.close()

    @property
    def draining(self) -> bool:
        return self._draining

    @property
    def crashed(self) -> bool:
        return self._crashed

    # ----------------------------------------------------------- chaos
    def _chaos_fire(self, point: str, tag: Optional[str] = None) -> bool:
        st = self._chaos
        return st.should_fire(point, tag) if st is not None else False

    # ------------------------------------------------------- admission
    async def submit(self, request: SolveRequest) -> RequestRecord:
        """Admit one request: returns its (live) record, or raises
        :class:`RequestRejected` — with ``retriable=True`` when the
        refusal is load/drain-shaped rather than malformed input."""
        assert self._loop is not None, \
            "AsyncSolveService.submit before start()"
        self.metrics.incr("submitted")
        rec = RequestRecord(id=uuid.uuid4().hex[:12], request=request,
                            submitted_at=time.time())
        if self._crashed:
            return self._reject(rec, "service crashed", retriable=True)
        if self._draining or self._closed:
            return self._reject(rec, "service is draining",
                                retriable=True)
        depth = self.metrics.queue_depth
        if depth >= self.cfg.max_queue:
            return self._reject(
                rec, f"queue full ({depth} >= max_queue="
                     f"{self.cfg.max_queue})", retriable=True)
        # malformed requests fail loudly at admission, not in the batch:
        # building the prototype Problem validates workload key + config
        try:
            problem = _as_problem(request.problem, request.cfg)
            lane_key = self._lane_key(problem, request)
        except Exception as e:
            rec.error = f"{type(e).__name__}: {e}"
            return self._reject(rec, rec.error, retriable=False)
        breaker = self._breakers.get(request.problem)
        if breaker is not None and not breaker.allow():
            self.metrics.incr("shed")
            return self._reject(
                rec, f"circuit open for workload {request.problem!r} "
                     f"(recent dispatches failing); retry after "
                     f"cooldown", retriable=True)
        self.records[rec.id] = rec
        self.metrics.incr("accepted")
        self.metrics.queue_delta(+1)
        if self._journal is not None:
            self._journal.admit(rec.id, request)
        if self._chaos_fire("serve_admit_drop"):
            # the crash-between-journal-and-schedule fault: the request
            # is admitted and durable but never scheduled — only a
            # journal replay on restart can recover it
            return rec
        self._schedule(rec, problem, lane_key)
        return rec

    def _schedule(self, rec: RequestRecord, problem,
                  lane_key: str) -> None:
        if rec.request.chaos_spec or self.cfg.batch_window_s <= 0 \
                or self.cfg.max_batch <= 1:
            self._dispatch([rec], problem, bucket_key=None)
        else:
            self._enqueue(rec, problem, lane_key)

    def _reject(self, rec: RequestRecord, why: str,
                *, retriable: bool) -> RequestRecord:
        rec.status = "rejected"
        rec.retriable = retriable
        rec.error = rec.error or why
        rec.finished_at = time.time()
        rec.done.set()
        self.metrics.incr("rejected")
        self.records[rec.id] = rec
        raise RequestRejected(why, rec)

    def _lane_key(self, problem, request: SolveRequest) -> str:
        """Compatibility key: requests coalesce only when the same
        Problem (by config fingerprint) runs under the same run options
        — one ``RunOptions`` drives a whole ``solve_many`` call."""
        opts = ";".join(f"{k}={request.options[k]!r}"
                        for k in sorted(request.options))
        return (f"{request.problem}|{_config_fingerprint(problem)}|"
                f"{opts}")

    # ---------------------------------------------------------- replay
    def _replay_journal(self) -> None:
        """Restart-and-replay (§21): re-admit every journaled request
        without a terminal record; re-dispatch journaled buckets as a
        group in their original order (same order ⇒ ``solve_many``
        re-plans the same bucket ⇒ same per-bucket checkpoint directory
        to resume from); everything else re-enters coalescing."""
        from repro.serve.journal import RequestJournal
        plan = RequestJournal.replay(self.cfg.journal_dir)
        if not plan.pending:
            return
        recs: Dict[str, RequestRecord] = {}
        for rid, request in plan.pending.items():
            rec = RequestRecord(id=rid, request=request,
                                submitted_at=time.time(), replayed=True)
            self.records[rid] = rec
            recs[rid] = rec
            self.metrics.incr("accepted")
            self.metrics.incr("replayed")
            self.metrics.queue_delta(+1)
        grouped = {rid for _, ids in plan.buckets for rid in ids}
        for key, ids in plan.buckets:
            ordered = [recs[rid] for rid in ids]
            problem = _as_problem(ordered[0].request.problem,
                                  ordered[0].request.cfg)
            for r in ordered:
                r.bucket_key = key
            if self._journal is not None:
                self._journal.bucket(key, ids)
            self._dispatch(ordered, problem, bucket_key=key,
                           resume=self._bucket_resume_available(
                               problem, ordered))
        for rid, rec in recs.items():
            if rid in grouped:
                continue
            try:
                problem = _as_problem(rec.request.problem,
                                      rec.request.cfg)
                lane_key = self._lane_key(problem, rec.request)
            except Exception as e:
                self._fail_now(rec, f"{type(e).__name__}: {e}")
                continue
            self._schedule(rec, problem, lane_key)

    def _bucket_resume_available(self, problem,
                                 recs: List[RequestRecord]) -> bool:
        """Would ``solve_many(resume=True)`` find checkpoints for this
        replayed group?  Pre-computed with the same plan/salt so the
        replay never trips solve_many's loud no-checkpoints error."""
        if not self.cfg.checkpoint_dir or not self.cfg.checkpoint_every:
            return False
        from repro.checkpoint import checkpointer as ckpt
        axes = problem.batch_axes()
        salt = (f"{problem.name or type(problem).__name__}|"
                f"{_config_fingerprint(problem)}")
        plan = batching.plan_buckets(
            [r.request.inputs for r in recs], axes,
            waste_budget=self.cfg.waste_budget, salt=salt)
        return any(
            ckpt.latest_step(Path(self.cfg.checkpoint_dir)
                             / f"bucket_{b.key}") is not None
            for b in plan)

    def _fail_now(self, rec: RequestRecord, error: str) -> None:
        """Terminal failure applied directly on the loop (replay of a
        request that no longer validates, watchdog reaping)."""
        rec.status = "failed"
        rec.error = error
        rec.finished_at = time.time()
        self.metrics.incr("failed")
        self.metrics.queue_delta(-1)
        if self._journal is not None:
            self._journal.done(rec.id, "failed")
        rec.done.set()
        self._wake_waiters(rec)

    # ------------------------------------------------------ scheduling
    def _enqueue(self, rec: RequestRecord, problem, lane_key: str) -> None:
        lane = self._lanes.get(lane_key)
        if lane is None:
            axes = problem.batch_axes()
            salt = f"{lane_key}"
            lane = _Lane(lane_key, problem, axes,
                         batching.OpenBucketPlanner(
                             axes, waste_budget=self.cfg.waste_budget,
                             salt=salt, max_members=self.cfg.max_batch))
            self._lanes[lane_key] = lane
        token = next(self._tokens)
        deadline = (rec.submitted_at + rec.request.deadline_s
                    if rec.request.deadline_s is not None else None)
        bucket = lane.planner.offer(token, rec.request.inputs,
                                    deadline=deadline)
        rec._token, rec._open, rec._lane = token, bucket, lane
        delay = self.cfg.batch_window_s
        earliest = bucket.earliest_deadline
        if earliest is not None:
            # dispatch a tight-deadline bucket early, leaving at least
            # half the member's remaining budget for the solve itself
            delay = max(0.0, min(delay,
                                 (earliest - time.time()) / 2.0))
        entry = lane.pending.get(id(bucket))
        if entry is None:
            # first member arms the coalescing deadline
            timer = self._loop.call_later(
                delay, self._flush_bucket, lane, id(bucket))
            lane.pending[id(bucket)] = [bucket, [rec], timer]
        else:
            entry[1].append(rec)
            if deadline is not None and delay < self.cfg.batch_window_s:
                entry[2].cancel()
                entry[2] = self._loop.call_later(
                    delay, self._flush_bucket, lane, id(bucket))
        if len(bucket) >= self.cfg.max_batch:
            self._flush_bucket(lane, id(bucket))

    def _flush_bucket(self, lane: _Lane, bucket_id: int) -> None:
        entry = lane.pending.pop(bucket_id, None)
        if entry is None:
            return                       # already flushed or cancelled
        bucket, recs, timer = entry
        timer.cancel()
        closed = lane.planner.close(bucket)
        # solve_many receives instances in bucket order; map each back
        token_to_rec = {r._token: r for r in recs}
        ordered = [token_to_rec[t] for t in closed.indices]
        for r in ordered:
            r._open = r._lane = None
            r.bucket_key = closed.key
        if self._journal is not None and len(ordered) > 1:
            self._journal.bucket(closed.key, [r.id for r in ordered])
        self._dispatch(ordered, lane.problem, bucket_key=closed.key)

    def _dispatch(self, recs: List[RequestRecord], problem,
                  *, bucket_key: Optional[str],
                  resume: bool = False) -> None:
        for r in recs:
            r.batch_size = len(recs)
        self.metrics.record_batch(len(recs))
        fut = self._loop.run_in_executor(
            self._executor, self._run_batch, recs, problem, resume)
        key = id(fut)
        self._inflight[key] = (fut, recs, time.monotonic())
        fut.add_done_callback(
            lambda f, _recs=recs: self._on_batch_done(key, _recs, f))

    # -------------------------------------------------- executor side
    def _run_batch(self, recs: List[RequestRecord], problem,
                   resume: bool = False) -> None:
        """Runs on a worker thread: one solve()/solve_many() for the
        whole batch, progress relayed to the loop per request, lane
        control (cancel/deadline/crash) returned to the driver at chunk
        boundaries, and poison-bucket quarantine on batch failure."""
        now = time.time()
        for r in recs:
            r.status = "running"
            r.started_at = now
            if self._chaos_fire("serve_bucket_poison"):
                r._inputs_override = _poison_inputs(r.request.inputs)

        if len(recs) == 1:
            recs[0].solution = self._solve_one(recs[0], problem,
                                               self._relay_for(recs[0]))
            return
        opts = dict(recs[0].request.options)
        kwargs: Dict[str, Any] = {}
        if self.cfg.checkpoint_dir and self.cfg.checkpoint_every:
            opts.setdefault("checkpoint_every", self.cfg.checkpoint_every)
            kwargs["checkpoint_dir"] = self.cfg.checkpoint_dir
            kwargs["resume"] = resume
        try:
            sols = solve_many(
                problem,
                [r._inputs_override or r.request.inputs for r in recs],
                mesh=self.mesh, waste_budget=self.cfg.waste_budget,
                progress_fn=self._relay_for_batch(recs),
                **kwargs, **opts)
        except Exception as err:
            if not self.cfg.quarantine or self._crashed:
                raise
            self._quarantine(recs, problem, err)
            return
        for r, s in zip(recs, sols):
            r.solution = s

    def _solve_one(self, rec: RequestRecord, problem, relay) -> Solution:
        from repro.resilience import chaos
        opts = dict(rec.request.options)
        inputs = rec._inputs_override or rec.request.inputs
        spec = rec.request.chaos_spec
        ctx = chaos.active_chaos(chaos.ChaosConfig.parse(spec)) \
            if spec else None
        if ctx is None:
            return solve(problem, *inputs, mesh=self.mesh,
                         progress_fn=relay, **opts)
        with ctx:
            return solve(problem, *inputs, mesh=self.mesh,
                         progress_fn=relay, **opts)

    def _quarantine(self, recs: List[RequestRecord], problem,
                    err: BaseException) -> None:
        """Poison-bucket isolation (§21): the coalesced dispatch failed
        as a unit, so re-dispatch each lane *solo* — only the offending
        request(s) fail, with the failure's recovery ledger attached,
        while siblings complete with trajectory parity (per-instance
        bundles are built unpadded, so a solo re-run replays the exact
        single-solve trajectory).  Runs inline on the worker thread."""
        self.metrics.incr("quarantined")
        bucket_report = getattr(err, "report", None)
        for r in recs:
            r.quarantined = True
            if self._crashed:
                return
            if r.status in TERMINAL or r._frozen_reason is not None:
                continue
            try:
                r.solution = self._solve_one(r, problem,
                                             self._relay_for(r))
            except Exception as solo:
                r._solo_error = solo
                rep = getattr(solo, "report", None)
                r.recovery = rep if rep is not None else bucket_report

    # ------------------------------------------------ progress control
    def _relay_for(self, rec: RequestRecord):
        """Per-chunk relay + control for a solo dispatch: push the
        event to the loop, then tell the driver to stop when the
        service crashed (chaos drill), the request was cancelled, or
        its deadline expired.  Runs on the worker thread."""
        loop = self._loop

        def relay(event):
            loop.call_soon_threadsafe(self._push_event, rec, event)
            if self._chaos_fire("serve_crash"):
                self._crashed = True
            if self._crashed:
                return {"stop": True}
            if rec.status in TERMINAL:
                # reaped by the watchdog: stop burning compute
                return {"stop": True}
            if rec._frozen_reason is None:
                if rec.cancel_requested:
                    rec._frozen_reason = "cancelled"
                elif _deadline_exceeded(rec):
                    rec._frozen_reason = "expired"
            if rec._frozen_reason is not None:
                return {"stop": True}
            return None

        return relay

    def _relay_for_batch(self, recs: List[RequestRecord]):
        """Batched relay + control: fan the per-instance sections out
        per request, then return the set of lanes to freeze (cancelled
        or expired) — the driver retires them at this chunk boundary
        exactly like converged lanes, siblings unperturbed."""
        loop = self._loop

        def relay(event):
            base = {k: v for k, v in event.items()
                    if k != "instances"}
            for j, st in event.get("instances", {}).items():
                loop.call_soon_threadsafe(
                    self._push_event, recs[j], {**base, **st})
            if self._chaos_fire("serve_crash"):
                self._crashed = True
            if self._crashed:
                return {"stop": True}
            now = time.time()
            cancel = []
            for j, r in enumerate(recs):
                if r._frozen_reason is not None:
                    continue
                if r.status in TERMINAL:
                    # reaped by the watchdog: freeze the lane so it
                    # stops burning compute
                    r._frozen_reason = "reaped"
                    cancel.append(j)
                elif r.cancel_requested:
                    r._frozen_reason = "cancelled"
                    cancel.append(j)
                elif _deadline_exceeded(r, now):
                    r._frozen_reason = "expired"
                    cancel.append(j)
            return {"cancel_instances": cancel} if cancel else None

        return relay

    # ------------------------------------------------------- loop side
    def _push_event(self, rec: RequestRecord, event: dict) -> None:
        if rec.status in TERMINAL:
            return
        rec.events.append(event)
        self._wake_waiters(rec)

    def _wake_waiters(self, rec: RequestRecord) -> None:
        for w in rec._waiters:
            if not w.done():
                w.set_result(None)
        rec._waiters.clear()

    def _breaker(self, problem_name: str) -> CircuitBreaker:
        b = self._breakers.get(problem_name)
        if b is None:
            b = CircuitBreaker(
                window=self.cfg.breaker_window,
                min_samples=self.cfg.breaker_min_samples,
                error_threshold=self.cfg.breaker_error_threshold,
                cooldown_s=self.cfg.breaker_cooldown_s)
            self._breakers[problem_name] = b
        return b

    def breaker_states(self) -> Dict[str, dict]:
        return {k: b.snapshot() for k, b in self._breakers.items()}

    def ready(self) -> Tuple[bool, dict]:
        """Readiness verdict for ``/v1/readyz``: can this service
        usefully accept traffic right now?  (Liveness — ``/v1/healthz``
        — stays true while draining; readiness does not.)"""
        open_breakers = [k for k, b in self._breakers.items()
                         if b.state != "closed"]
        depth = self.metrics.queue_depth
        detail = {"draining": self._draining, "crashed": self._crashed,
                  "closed": self._closed,
                  "queue_depth": depth, "max_queue": self.cfg.max_queue,
                  "open_breakers": open_breakers}
        ok = (not self._draining and not self._closed
              and not self._crashed and depth < self.cfg.max_queue
              and not open_breakers)
        return ok, detail

    def _on_batch_done(self, key: int, recs: List[RequestRecord],
                       fut) -> None:
        self._inflight.pop(key, None)
        if self._crashed:
            # simulated hard crash: a real dead process journals and
            # finalizes nothing — restart-and-replay owns these records
            return
        err = None if fut.cancelled() else fut.exception()
        now = time.time()
        for r in recs:
            if r.status in TERMINAL:
                continue
            r.finished_at = now
            ok = True
            if r._frozen_reason == "cancelled":
                r.status = "cancelled"
                r.error = "cancelled in flight (lane frozen at chunk " \
                          "boundary)"
                self.metrics.incr("cancelled")
            elif r._frozen_reason == "expired":
                r.status = "failed"
                r.error = (f"deadline_s={r.request.deadline_s} exceeded "
                           f"(lane frozen at chunk boundary)")
                self.metrics.incr("expired")
                self.metrics.incr("failed")
            elif r._solo_error is not None:
                ok = False
                r.status = "failed"
                r.error = (f"{type(r._solo_error).__name__}: "
                           f"{r._solo_error}")
                self.metrics.incr("failed")
            elif err is not None:
                ok = False
                r.status = "failed"
                r.error = f"{type(err).__name__}: {err}"
                if r.recovery is None:
                    r.recovery = getattr(err, "report", None)
                self.metrics.incr("failed")
            else:
                r.status = "done"
                self.metrics.incr("completed")
                self.metrics.record_latency(r.latency_s)
                sol = r.solution
                if sol is not None and sol.recovery is not None \
                        and r.recovery is None:
                    # the bucket's report is shared across lanes: slice
                    # it to what this lane could have witnessed
                    last = (sol.log.converged_at
                            if sol.log.converged_at is not None
                            else sol.log.cancelled_at)
                    r.recovery = sol.recovery.for_range(last)
            if r.recovery is not None:
                # terminal, so _push_event would drop it — append
                # directly; the ndjson stream drains remaining events
                # before writing its end line
                r.events.append({"kind": "recovery",
                                 **r.recovery.to_json()})
            self._breaker(r.request.problem).record(ok, r.latency_s)
            if self._journal is not None:
                self._journal.done(r.id, r.status)
            self.metrics.queue_delta(-1)
            r.done.set()
            self._wake_waiters(r)

    # -------------------------------------------------------- watchdog
    async def _watchdog(self) -> None:
        """Reap hung dispatches: an in-flight batch older than
        ``dispatch_timeout_s`` fails its requests (the worker thread
        cannot be killed — its eventual completion is a no-op against
        the already-terminal records) and feeds the breaker."""
        timeout = float(self.cfg.dispatch_timeout_s)
        interval = max(min(timeout / 4.0, 1.0), 0.01)
        while not self._closed:
            await asyncio.sleep(interval)
            if self._crashed:
                continue
            now = time.monotonic()
            for key, (fut, recs, t0) in list(self._inflight.items()):
                if fut.done() or (now - t0) <= timeout:
                    continue
                self._inflight.pop(key, None)
                self.metrics.incr("hung")
                for r in recs:
                    if r.status in TERMINAL:
                        continue
                    self._breaker(r.request.problem).record(False)
                    self._fail_now(
                        r, f"hung dispatch: no completion after "
                           f"{now - t0:.1f}s (dispatch_timeout_s="
                           f"{timeout})")

    # --------------------------------------------------------- queries
    def record(self, request_id: str) -> RequestRecord:
        try:
            return self.records[request_id]
        except KeyError:
            raise KeyError(f"unknown request id {request_id!r}") from None

    async def result(self, request_id: str,
                     timeout: Optional[float] = None) -> RequestRecord:
        """Wait for a terminal state and return the record."""
        rec = self.record(request_id)
        await asyncio.wait_for(rec.done.wait(), timeout)
        return rec

    async def wait_events(self, request_id: str, cursor: int = 0,
                          timeout: float = 1.0
                          ) -> Tuple[List[dict], bool, int]:
        """Long-poll progress: events past ``cursor`` (possibly empty on
        timeout), whether the request is terminal, and the new cursor.
        This is the transport-friendly streaming primitive — the HTTP
        endpoint loops it and writes JSON lines."""
        rec = self.record(request_id)
        if cursor >= len(rec.events) and not rec.done.is_set():
            waiter = self._loop.create_future()
            rec._waiters.append(waiter)
            try:
                await asyncio.wait_for(waiter, timeout)
            except asyncio.TimeoutError:
                pass
            finally:
                if waiter in rec._waiters:
                    rec._waiters.remove(waiter)
        events = rec.events[cursor:]
        return events, rec.done.is_set(), cursor + len(events)

    async def cancel(self, request_id: str) -> bool:
        """Cancel a request.  Queued: withdrawn from its open bucket
        and terminal immediately.  Running: flagged — the dispatch
        relay freezes its lane at the next chunk boundary (siblings
        unperturbed) and the record goes terminal when the freeze
        lands.  Terminal: returns False."""
        rec = self.record(request_id)
        if rec.status == "running":
            if rec.cancel_requested or rec._frozen_reason is not None:
                return False
            rec.cancel_requested = True
            return True
        if rec.status != "queued" or rec._open is None:
            return False
        lane = rec._lane
        lane.planner.discard(rec._open, rec._token)
        entry = lane.pending.get(id(rec._open))
        if entry is not None:
            _, recs, timer = entry
            recs.remove(rec)
            if not recs:
                timer.cancel()
                lane.pending.pop(id(rec._open), None)
        rec._open = rec._lane = None
        rec.status = "cancelled"
        rec.finished_at = time.time()
        rec.done.set()
        self.metrics.incr("cancelled")
        self.metrics.queue_delta(-1)
        if self._journal is not None:
            self._journal.done(rec.id, "cancelled")
        self._wake_waiters(rec)
        return True

    # ----------------------------------------------------------- drain
    async def drain(self) -> dict:
        """Graceful shutdown of traffic: stop admitting, reject every
        still-queued request with the retriable status, and wait for
        in-flight batches to finish.  Returns a summary dict."""
        self._draining = True
        rejected = 0
        for lane in self._lanes.values():
            for bucket, recs, timer in list(lane.pending.values()):
                timer.cancel()
                for rec in recs:
                    lane.planner.discard(bucket, rec._token)
                    rec._open = rec._lane = None
                    rec.status = "rejected"
                    rec.retriable = True
                    rec.error = "service drained before dispatch"
                    rec.finished_at = time.time()
                    rec.done.set()
                    self.metrics.incr("rejected")
                    self.metrics.queue_delta(-1)
                    if self._journal is not None:
                        self._journal.done(rec.id, "rejected")
                    self._wake_waiters(rec)
                    rejected += 1
            lane.pending.clear()
        inflight = [f for (f, _, _) in self._inflight.values()]
        if inflight:
            await asyncio.gather(*inflight, return_exceptions=True)
        return {"rejected_queued": rejected,
                "finished_inflight": len(inflight)}

    async def close(self) -> None:
        """Drain, then tear down the worker executor."""
        if not self._closed:
            await self.drain()
            self._closed = True
            if self._watchdog_task is not None:
                self._watchdog_task.cancel()
            self._executor.shutdown(wait=True)
            if self._journal is not None:
                self._journal.close()

    async def abandon(self) -> None:
        """Simulated hard crash (the §21 kill/restart drill): stop
        admitting, tell in-flight dispatches to stop at their next
        chunk boundary, and tear down WITHOUT journaling terminal
        states or rejecting queued work — a dead process writes
        nothing, so a service restarted over the same ``journal_dir``
        owes exactly what this one abandoned."""
        self._crashed = True
        self._closed = True
        if self._watchdog_task is not None:
            self._watchdog_task.cancel()
        inflight = [f for (f, _, _) in self._inflight.values()]
        if inflight:
            await asyncio.gather(*inflight, return_exceptions=True)
        self._executor.shutdown(wait=True)
        if self._journal is not None:
            self._journal.close()


def _deadline_exceeded(rec: RequestRecord,
                       now: Optional[float] = None) -> bool:
    d = rec.request.deadline_s
    if d is None:
        return False
    return ((now if now is not None else time.time())
            - rec.submitted_at) > d


def _poison_inputs(inputs: Tuple[Any, ...]) -> Tuple[Any, ...]:
    """NaN-poison the first float input array — the serve-level
    analogue of ``chaos.poison_tree``, applied to a request's inputs
    before dispatch (``serve_bucket_poison``).  The poison survives a
    quarantine re-dispatch: the lane is broken, not the bucket."""
    out = list(inputs)
    for i, x in enumerate(out):
        a = np.asarray(x)
        if np.issubdtype(a.dtype, np.floating):
            a = a.copy()
            a.reshape(-1)[0] = np.nan
            out[i] = a
            break
    return tuple(out)
