"""Wire/journal codecs for serve requests (DESIGN.md §20/§21).

Factored out of ``serve.server`` so the request journal can round-trip
requests without importing the HTTP transport (which imports the
service, which owns the journal — a cycle otherwise).  Two families:

- the HTTP wire format: configs as plain dicts decoded through the
  per-workload config dataclass, inputs as nested JSON lists;
- the journal format: lossless base64 array records
  (:func:`encode_array`/:func:`decode_array`) plus JSON-safe
  config/option encodings (:func:`encode_config`/:func:`encode_options`)
  that survive a crash-restart round trip bit-for-bit.
"""
from __future__ import annotations

import base64
import dataclasses
import importlib
from typing import Any, Dict, Optional, Tuple

import numpy as np

#: problem key -> (module, config dataclass) for decoding ``cfg`` dicts;
#: in-process callers pass config objects directly instead
_CONFIG_TYPES: Dict[str, Tuple[str, str]] = {
    "deconvolve": ("repro.imaging.condat", "SolverConfig"),
    "scdl": ("repro.imaging.scdl", "SCDLConfig"),
    "lowrank": ("repro.imaging.lowrank", "CompletionConfig"),
}


def decode_config(problem: str, cfg: Optional[dict]):
    if cfg is None:
        return None
    if not isinstance(cfg, dict):
        raise ValueError(f"cfg must be a JSON object, got "
                         f"{type(cfg).__name__}")
    if problem not in _CONFIG_TYPES:
        raise ValueError(
            f"no config codec for workload {problem!r}; known: "
            f"{sorted(_CONFIG_TYPES)}")
    mod, name = _CONFIG_TYPES[problem]
    cls = getattr(importlib.import_module(mod), name)
    return cls(**cfg)


def encode_config(cfg) -> Optional[dict]:
    """A workload config dataclass as a JSON-safe dict (inverse of
    :func:`decode_config` for the journal)."""
    if cfg is None:
        return None
    if isinstance(cfg, dict):
        return dict(cfg)
    return dataclasses.asdict(cfg)


def decode_options(options: Optional[dict]) -> Dict[str, Any]:
    """Run-control dict off the wire; the one structured field is
    ``resilience`` (a dict of ResilienceConfig overrides)."""
    opts = dict(options or {})
    res = opts.get("resilience")
    if isinstance(res, dict):
        from repro.resilience.recovery import ResilienceConfig
        opts["resilience"] = ResilienceConfig(**res)
    return opts


def encode_options(options: Optional[dict]) -> Dict[str, Any]:
    """Run-control dict as JSON (inverse of :func:`decode_options`).
    A ``ResilienceConfig`` is flattened to its JSON-safe fields —
    callable hooks (``rollback_rescale``) and extra exception types
    (``transient_types``) cannot be journaled and are dropped with the
    documented caveat that a replayed request falls back to the
    defaults for those two fields."""
    opts = dict(options or {})
    res = opts.get("resilience")
    if res is not None and not isinstance(res, dict):
        d = dataclasses.asdict(res)
        d.pop("rollback_rescale", None)
        d.pop("transient_types", None)
        opts["resilience"] = d
    return opts


def decode_inputs(inputs) -> Tuple[np.ndarray, ...]:
    """Wire inputs: nested JSON lists (decoded float32 unless a
    ``{"data", "dtype"}`` record overrides) or journal array records
    (``{"b64", "dtype", "shape"}``)."""
    if not isinstance(inputs, (list, tuple)):
        raise ValueError("inputs must be a JSON array of arrays")
    out = []
    for x in inputs:
        if isinstance(x, dict) and "b64" in x:
            out.append(decode_array(x))
        elif isinstance(x, dict):
            out.append(np.asarray(x["data"],
                                  dtype=np.dtype(x.get("dtype",
                                                       "float32"))))
        else:
            out.append(np.asarray(x, dtype=np.float32))
    return tuple(out)


def encode_array(a) -> dict:
    """Lossless journal record of one array: raw bytes base64'd with
    dtype/shape — exact replay beats human-readable here."""
    a = np.ascontiguousarray(np.asarray(a))
    return {"dtype": a.dtype.str, "shape": list(a.shape),
            "b64": base64.b64encode(a.tobytes()).decode("ascii")}


def decode_array(d: dict) -> np.ndarray:
    return np.frombuffer(
        base64.b64decode(d["b64"]), dtype=np.dtype(d["dtype"])
    ).reshape(d["shape"]).copy()
