"""Nuclear-norm proximal operators for the low-rank deconvolution (Eq. 3).

Sequential reference: full SVD of the (n_images, S*S) pixel matrix —
exactly what the paper's driver does after reassembling the stack, and
exactly why its low-rank speedup saturates at 1.2-2.5x.

Distributed version (beyond-paper, DESIGN.md §2): randomized range-finder
SVT that never gathers the stack.  All cross-partition traffic is two
psum-reduced Gram/projection matrices of size (r, r) and (r, p):

    Y = A @ Omega                    (local rows)
    Q = Y chol(Y^T Y)^-T             (Y^T Y psum, r x r)
    B = Q^T A                        (psum, r x p)
    U S V^T = svd(B)                 (replicated, tiny)
    A_svt = (Q U) max(S - t, 0) V^T  (local rows)

The iteration count of the enclosing primal-dual loop tolerates the
range-finder approximation (rank r chosen >= expected galaxy-stack rank).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def svt(mat: jax.Array, thresh) -> jax.Array:
    """Exact singular-value thresholding (sequential reference)."""
    u, s, vt = jnp.linalg.svd(mat, full_matrices=False)
    s = jnp.maximum(s - thresh, 0.0)
    return (u * s[None, :]) @ vt


def randomized_svt_local(a_local: jax.Array, omega: jax.Array, thresh,
                         axes=None, eps: float = 1e-6) -> jax.Array:
    """SVT of the row-sharded matrix from inside a shard_map/bundle_map.

    a_local: (n_local, p) rows of A; omega: (p, r) replicated test matrix;
    ``axes``: mesh axes to psum over (None == single partition).
    """
    y = a_local @ omega                              # (n_loc, r)
    gram = y.T @ y                                   # (r, r)
    if axes:
        gram = jax.lax.psum(gram, axes)
    # orthogonalise through the Gram eigendecomposition (rank-deficient
    # safe: null directions are clipped, unlike a regularised Cholesky)
    evals, evecs = jnp.linalg.eigh(gram)
    scale = jnp.where(evals > eps * jnp.max(evals),
                      jax.lax.rsqrt(jnp.maximum(evals, 1e-30)), 0.0)
    q = y @ (evecs * scale[None, :])                 # (n_loc, r) orthonormal
    b = q.T @ a_local                                # (r, p)
    if axes:
        b = jax.lax.psum(b, axes)
    u, s, vt = jnp.linalg.svd(b, full_matrices=False)
    s = jnp.maximum(s - thresh, 0.0)
    return (q @ u) * s[None, :] @ vt                 # (n_loc, p)


def make_test_matrix(p: int, rank: int, oversample: int = 8,
                     key: Optional[jax.Array] = None) -> jax.Array:
    key = key if key is not None else jax.random.PRNGKey(7)
    return jax.random.normal(key, (p, rank + oversample)) / jnp.sqrt(p)
