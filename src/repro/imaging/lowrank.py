"""Nuclear-norm proximal operators for the low-rank deconvolution (Eq. 3).

Sequential reference: full SVD of the (n_images, S*S) pixel matrix —
exactly what the paper's driver does after reassembling the stack, and
exactly why its low-rank speedup saturates at 1.2-2.5x.

Distributed version (beyond-paper, DESIGN.md §2): randomized range-finder
SVT that never gathers the stack.  All cross-partition traffic is two
psum-reduced Gram/projection matrices of size (r, r) and (r, p):

    Y = A @ Omega                    (local rows)
    Q = Y chol(Y^T Y)^-T             (Y^T Y psum, r x r)
    B = Q^T A                        (psum, r x p)
    U S V^T = svd(B)                 (replicated, tiny)
    A_svt = (Q U) max(S - t, 0) V^T  (local rows)

The iteration count of the enclosing primal-dual loop tolerates the
range-finder approximation (rank r chosen >= expected galaxy-stack rank).

Beyond the operators, this module declares a third first-class workload
on the generic engine (DESIGN.md §14): :class:`LowRankCompletionProblem`
(registered ``"lowrank"``) — distributed low-rank matrix completion via
proximal gradient + the randomized SVT above.  It exists to prove the
Problem API generalizes beyond the paper's two use cases: the entire
workload is the <50-line declaration at the bottom of this file.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.bundle import Bundle, gather
from repro.core.problem import Problem, register


def svt(mat: jax.Array, thresh) -> jax.Array:
    """Exact singular-value thresholding (sequential reference)."""
    u, s, vt = jnp.linalg.svd(mat, full_matrices=False)
    s = jnp.maximum(s - thresh, 0.0)
    return (u * s[None, :]) @ vt


def randomized_svt_local(a_local: jax.Array, omega: jax.Array, thresh,
                         axes=None, eps: float = 1e-6) -> jax.Array:
    """SVT of the row-sharded matrix from inside a shard_map/bundle_map.

    a_local: (n_local, p) rows of A; omega: (p, r) replicated test matrix;
    ``axes``: mesh axes to psum over (None == single partition).
    """
    y = a_local @ omega                              # (n_loc, r)
    gram = y.T @ y                                   # (r, r)
    if axes:
        gram = jax.lax.psum(gram, axes)
    # orthogonalise through the Gram eigendecomposition (rank-deficient
    # safe: null directions are clipped, unlike a regularised Cholesky)
    evals, evecs = jnp.linalg.eigh(gram)
    scale = jnp.where(evals > eps * jnp.max(evals),
                      jax.lax.rsqrt(jnp.maximum(evals, 1e-30)), 0.0)
    q = y @ (evecs * scale[None, :])                 # (n_loc, r) orthonormal
    b = q.T @ a_local                                # (r, p)
    if axes:
        b = jax.lax.psum(b, axes)
    u, s, vt = jnp.linalg.svd(b, full_matrices=False)
    s = jnp.maximum(s - thresh, 0.0)
    return (q @ u) * s[None, :] @ vt                 # (n_loc, p)


def make_test_matrix(p: int, rank: int, oversample: int = 8,
                     key: Optional[jax.Array] = None) -> jax.Array:
    key = key if key is not None else jax.random.PRNGKey(7)
    return jax.random.normal(key, (p, rank + oversample)) / jnp.sqrt(p)


# ---------------------------------------------------------------------
# Workload: distributed low-rank matrix completion
# ---------------------------------------------------------------------

@dataclass(frozen=True)
class CompletionConfig:
    """min_X 0.5||M o (X - Y)||_F^2 + lam ||X||_* by proximal gradient:
    X <- SVT(X - step * M o (X - Y), lam * step), SVT distributed via
    the randomized range finder (rows of X sharded, two psums/iter)."""
    rank: int = 16                 # range-finder target rank
    lam: float = 0.1               # nuclear-norm weight
    step: float = 1.0              # <= 1/L; L = 1 for the masked id.
    oversample: int = 8
    max_iter: int = 200
    tol: float = 1e-4


def _masked_residual(d):
    return d["M"] * (d["X"] - d["Y"])


def nuclear_norm_rf(X_loc, omega, axes):
    """Range-finder nuclear norm of a row-sharded matrix (psum-reduced
    (r, r) Gram of the projection, replicated eigen-sqrt-sum) — exact
    when rank(X) <= r, e.g. for every post-SVT iterate.  Shared by the
    lowrank-mode deconvolution objective and the completion workload."""
    y = X_loc @ omega
    gram = y.T @ y
    if axes:
        gram = jax.lax.psum(gram, axes)
    s2 = jnp.linalg.eigvalsh(gram)
    return jnp.sum(jnp.sqrt(jnp.maximum(s2, 0.0)))


@register("lowrank")
class LowRankCompletionProblem(Problem):
    """Low-rank completion of a row-sharded matrix, declared once.

    Inputs: ``(Y, M)`` — observations (n, p) and a {0,1} mask of the
    same shape.  The broadcast side carries only the constant SVT test
    matrix, so there is no ``refresh_replicated``; the declared
    ``light_step`` + ``cost`` unlock every objective cadence the engine
    offers (integer ``cost_every`` and ``"chunk"``).
    """

    def __init__(self, cfg: Optional[CompletionConfig] = None, key=None):
        self.cfg = cfg if cfg is not None else CompletionConfig()
        self.key = key

    def init_bundle(self, inputs, mesh) -> Bundle:
        Y, M = inputs
        M = jnp.asarray(M, Y.dtype)
        data = {"Y": Y * M, "M": M, "X": Y * M}
        omega = make_test_matrix(Y.shape[1], self.cfg.rank,
                                 self.cfg.oversample, key=self.key)
        return Bundle.create(data, mesh=mesh,
                             replicated={"omega": omega.astype(Y.dtype)})

    def _iterate(self, d, rep, axes):
        cfg = self.cfg
        X_half = d["X"] - cfg.step * _masked_residual(d)
        X_new = randomized_svt_local(X_half, rep["omega"],
                                     cfg.lam * cfg.step, axes=axes or None)
        return dict(d, X=X_new)

    def full_step(self, d, rep, axes):
        d_new = self._iterate(d, rep, axes)
        out = self.cost(d_new, rep, axes)
        return d_new, out

    def light_step(self, d, rep, axes):
        return self._iterate(d, rep, axes)

    def cost(self, d, rep, axes):
        data_part = 0.5 * jnp.sum(_masked_residual(d) ** 2)
        if axes:
            data_part = jax.lax.psum(data_part, axes)
        nuc = nuclear_norm_rf(d["X"], rep["omega"], axes)
        return {"cost": data_part + self.cfg.lam * nuc}

    def finalize(self, bundle, log):
        return gather(bundle)["X"], {}

    def batch_axes(self):
        from repro.core.batching import BatchAxes
        # (Y, M) are row-major; the SVT test matrix is drawn from a
        # fixed key + config shape only, so one copy serves the bucket.
        # ``key`` is a constructor attribute shared by declaration.
        return BatchAxes(record_axes=(0, 0), shared_in_batch=("omega",),
                         instance_invariant=("key",))
