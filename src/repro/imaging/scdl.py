"""Algorithm 2 — Sparse Coupled Dictionary Learning over the bundle.

ADMM for Eq. (4): recover coupled low/high-resolution dictionaries
X_l, X_h and shared sparse codes from paired observations S_l, S_h.

Distribution (mirrors the paper's pseudo-code):
  1.   parallelise S_h, S_l over samples (K axis)        -> Bundle.create
  2/3. initialise dictionaries from random bundle samples -> init_dicts
  4/5. zip + enrich with W_h, W_l, Y1, Y2, Y3 (+ the folded
       splitting-term right-hand sides Z1, Z2)            -> same bundle
  6-10. per iteration:
     7. broadcast X_h, X_l + the factor-once solve operators for
        (2 X^T X + (c+c3) I)^-1 (DESIGN.md §13)
        -> replicated side of the bundle
     8. map: local W/P/Q/Y updates on each sample block
     9. map-reduce: psum outer products S W^T (P x A), W W^T (A x A)
        -> the all-reduce that replaces the paper's reduce-to-driver
    10. replicated dictionary update (Eq. 6-7) + column norm clipping

The sequential reference is the same step with an unpartitioned bundle —
used by tests to assert distributed == sequential math.

Factor-once broadcast (DESIGN.md §13): the ridge Gram matrices
``Gh = 2 Xh^T Xh + (c1+c3) I`` / ``Gl`` depend only on the replicated
dictionaries, so they are Cholesky-factored ONCE per iteration inside
the scan carry (:func:`make_refresh_fn`) instead of re-built and
LU-solved per partition per iteration.  The broadcast payload is the
factor *applied*: the explicit symmetric inverse when the patch
dimension dominates, or the Woodbury companion ``(c/2 I_P + X X^T)^-1 X``
when P < A (the GS/HS patch shapes: the Gram is a rank-P update of the
ridge), so every sample block's W solve is one or two GEMMs.

The splitting variables P, Q are not bundle state: step 8 only ever
consumes them through the right-hand-side combinations
``Z1 = c1 P + Y1 - Y3 + c3 Wl`` and ``Z2 = c2 Q + Y2 + Y3``, which the
fused elementwise kernel emits directly.  The multipliers and Z terms
live as ONE stacked (K, 5, A) leaf ``YZ = [Y1, Y2, Y3, Z1, Z2]`` so the
whole elementwise tail is one read/one write (kernels/admm_elwise).

Deviation note (DESIGN.md §9): the paper's Eq. (6-7) write the dictionary
update as X += S W^T/(phi + delta); we implement the standard damped
least-squares solve X = (S W^T)(phi + delta I)^-1 that this abbreviates
(Fotiadou et al.'s Alg. 1), with unit-norm column clipping per Eq. (4).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from repro.core.bundle import Bundle
from repro.core.problem import Problem, register, solve
from repro.kernels.admm_elwise.ops import admm_elwise
from repro.kernels.dict_outer.ops import dict_outer_pair


@dataclass(frozen=True)
class SCDLConfig:
    n_atoms: int = 512             # A
    lam_h: float = 0.01
    lam_l: float = 0.01
    c1: float = 0.4
    c2: float = 0.4
    c3: float = 0.8
    delta: float = 1e-2
    max_iter: int = 100
    tol: float = 0.0               # paper runs to i_max


def init_dicts(S_h, S_l, cfg: SCDLConfig, key=None):
    """Steps 2/3: random sample columns -> initial unit-norm dictionaries."""
    key = key if key is not None else jax.random.PRNGKey(3)
    K = S_h.shape[1]
    idx = jax.random.choice(key, K, (cfg.n_atoms,), replace=False)
    X_h = S_h[:, idx]
    X_l = S_l[:, idx]
    X_h = X_h / jnp.maximum(jnp.linalg.norm(X_h, axis=0, keepdims=True), 1e-8)
    X_l = X_l / jnp.maximum(jnp.linalg.norm(X_l, axis=0, keepdims=True), 1e-8)
    return X_h, X_l


def _solve_factor(X, c):
    """Factor-once payload for applying ``(2 X^T X + c I)^-1`` (X: (P, A)).

    The Gram is a rank-P update of the ridge, so for P < A (the paper's
    patch shapes) the O(.^3) work happens on the (P, P) Woodbury
    companion ``B = c/2 I + X X^T``:

        (2 X^T X + c I)^-1 = (1/c) [I - X^T (c/2 I + X X^T)^-1 X]

    Three regimes, chosen by static shape (GEMM flops per K sample rows
    in brackets):

    - ``2P < A`` — *thin apply*: broadcast ``C = B^-1 X`` (P, A) and
      apply the bracketed form directly [4PA per row].
    - ``P < A <= 2P`` — *dense apply, Woodbury build*: materialise the
      (A, A) inverse from ``C`` (one (A, P)x(P, A) GEMM at build time),
      apply as a single square GEMM [2A^2 per row].
    - ``P >= A`` — *dense apply, direct build*: Cholesky the (A, A) Gram
      and solve against the identity.

    Dense payloads also carry ``B2 = 2 X G^-1`` so the per-block solve
    folds the right-hand-side assembly: ``w = (2 S X + Z) G^-1 =
    S B2 + Z G^-1`` — no rhs materialisation pass.  Either way the
    factorization happens once per iteration, in the replicated carry,
    not per partition (DESIGN.md §13).
    """
    P, A = X.shape
    eye = lambda n: jnp.eye(n, dtype=X.dtype)
    if P < A:
        B = 0.5 * c * eye(P) + X @ X.T
        C = jsl.cho_solve((jnp.linalg.cholesky(B), True), X)
        if 2 * P < A:
            return {"C": C}
        Gi = (eye(A) - X.T @ C) / c
    else:
        G = 2.0 * X.T @ X + c * eye(A)
        Gi = jsl.cho_solve((jnp.linalg.cholesky(G), True), eye(A))
    return {"Gi": Gi, "B2": 2.0 * X @ Gi}


def _ridge_solve(S, Z, X, F, c):
    """Row-wise solve ``(2 X^T X + c I) w = 2 S @ X + Z`` with the
    broadcast factor ``F`` from :func:`_solve_factor` — pure GEMMs on
    the sample block."""
    if "Gi" in F:
        return S @ F["B2"] + Z @ F["Gi"]
    rhs = 2.0 * (S @ X) + Z
    return (rhs - (rhs @ X.T) @ F["C"]) / c


def broadcast_factors(Xh, Xl, cfg: SCDLConfig):
    """Step 7's broadcast payload: the dictionaries plus the factor-once
    solve operators for the W ridge systems."""
    return {"Xh": Xh, "Xl": Xl,
            "Fh": _solve_factor(Xh, cfg.c1 + cfg.c3),
            "Fl": _solve_factor(Xl, cfg.c2 + cfg.c3)}


def build_bundle(S_h, S_l, cfg: SCDLConfig, mesh=None, key=None
                 ) -> Bundle:
    """Steps 1-5: sample-axis bundle; record axis = K (transposed blocks).

    Beyond the paper's arrays the replicated side carries the solve
    factors (step 7) and the constant objective normalizers ||S||^2
    (recomputed every iteration in the seed; they never change)."""
    X_h, X_l = init_dicts(S_h, S_l, cfg, key)
    A = cfg.n_atoms
    K = S_h.shape[1]
    data = {
        "Sh": S_h.T, "Sl": S_l.T,              # (K, P) / (K, M)
        "Wh": jnp.zeros((K, A), S_h.dtype),    # (K, A) sample-major codes
        "Wl": jnp.zeros((K, A), S_h.dtype),
        # stacked multiplier state [Y1, Y2, Y3, Z1, Z2]
        "YZ": jnp.zeros((K, 5, A), S_h.dtype),
    }
    replicated = dict(broadcast_factors(X_h, X_l, cfg),
                      n_h=jnp.sum(S_h.astype(jnp.float32) ** 2),
                      n_l=jnp.sum(S_l.astype(jnp.float32) ** 2))
    return Bundle.create(data, mesh=mesh, replicated=replicated)


def _code_updates(d, rep, cfg: SCDLConfig):
    """Step 8: local ADMM updates for one sample block (all (K_loc, .)).

    The ridge systems are solved against the broadcast factor-once
    operators (GEMMs; the Gram build/factorization lives in
    :func:`make_refresh_fn`), and the soft-threshold + three dual
    updates run through the fused ``admm_elwise`` kernel — one read and
    one write of each (K_loc, A) array instead of ~5 full passes."""
    c1, c2, c3 = cfg.c1, cfg.c2, cfg.c3
    Wh = _ridge_solve(d["Sh"], d["YZ"][:, 3], rep["Xh"], rep["Fh"],
                      c1 + c3)
    Wl = _ridge_solve(d["Sl"], d["YZ"][:, 4] + c3 * Wh, rep["Xl"],
                      rep["Fl"], c2 + c3)

    YZ = admm_elwise(Wh, Wl, d["YZ"], c1=c1, c2=c2, c3=c3,
                     t1=cfg.lam_h / c1, t2=cfg.lam_l / c2)
    return dict(d, Wh=Wh, Wl=Wl, YZ=YZ)


def _outer_products(d, axes):
    """Step 9: psum-reduced S W^T and W W^T (the paper's map-reduce).

    Both coupled pairs run through the fused ``dict_outer_pair`` kernel:
    each (block_k, A) code tile is read from HBM once and feeds both its
    S^T W and W^T W accumulators while resident in VMEM."""
    ShWh, SlWl, phi_h, phi_l = dict_outer_pair(
        d["Sh"], d["Sl"], d["Wh"], d["Wl"])
    parts = {"ShWh": ShWh, "SlWl": SlWl, "phi_h": phi_h, "phi_l": phi_l}
    if axes:
        parts = jax.tree.map(lambda x: jax.lax.psum(x, axes), parts)
    return parts


def _dict_update(rep, outer, cfg: SCDLConfig):
    """Step 10 / Eq. (6-7): damped LS dictionary update + column norms.

    ``phi + delta I`` is SPD (phi = W^T W is PSD, delta > 0), so the
    damped solve goes through Cholesky as well."""
    A = rep["Xh"].shape[1]
    eye = jnp.eye(A, dtype=rep["Xh"].dtype)
    dt = rep["Xh"].dtype
    ch = jnp.linalg.cholesky(outer["phi_h"].astype(dt) + cfg.delta * eye)
    cl = jnp.linalg.cholesky(outer["phi_l"].astype(dt) + cfg.delta * eye)
    Xh = jsl.cho_solve((ch, True), outer["ShWh"].T.astype(dt)).T
    Xl = jsl.cho_solve((cl, True), outer["SlWl"].T.astype(dt)).T
    clip = lambda X: X / jnp.maximum(
        jnp.linalg.norm(X, axis=0, keepdims=True), 1.0)
    return {"Xh": clip(Xh), "Xl": clip(Xl)}


def _iterate(d, rep, axes, cfg: SCDLConfig):
    """Steps 8-10 minus the objective: the shared body of the full and
    cost-free step variants."""
    d = _code_updates(d, rep, cfg)
    outer = _outer_products(d, axes)
    new_dicts = _dict_update(rep, outer, cfg)
    return d, new_dicts


def make_step_fn(cfg: SCDLConfig):
    """One full ADMM iteration (steps 7-10) as a bundle step.

    Returns (new_data, {"cost", "nrmse_h", "nrmse_l", "Xh", "Xl"}): the
    dictionaries ride in the reduced output (replicated), feeding the
    next iteration's broadcast — the driver folds them (and the
    factor-once solve operators) back into the replicated side via
    :func:`make_refresh_fn`.
    """

    def step(d, rep, axes):
        d, new_dicts = _iterate(d, rep, axes, cfg)
        # augmented-Lagrangian data terms (the paper's Fig. 14 metric is
        # the reconstruction error of the *calculated dictionaries*)
        res_h = jnp.sum((d["Sh"] - d["Wh"] @ new_dicts["Xh"].T) ** 2)
        res_l = jnp.sum((d["Sl"] - d["Wl"] @ new_dicts["Xl"].T) ** 2)
        parts = {"res_h": res_h, "res_l": res_l}
        if axes:
            parts = jax.tree.map(lambda x: jax.lax.psum(x, axes), parts)
        nrmse_h = jnp.sqrt(parts["res_h"] / (rep["n_h"] + 1e-12))
        nrmse_l = jnp.sqrt(parts["res_l"] / (rep["n_l"] + 1e-12))
        out = {"cost": 0.5 * (nrmse_h + nrmse_l),
               "nrmse_h": nrmse_h, "nrmse_l": nrmse_l, **new_dicts}
        return d, out

    return step


def make_light_step_fn(cfg: SCDLConfig):
    """The same iteration without the objective evaluation — the
    ``cost_every`` fast path.  Skips the full (K_loc, P)/(K_loc, M)
    reconstructions ``Wh @ Xh^T`` / ``Wl @ Xl^T`` that exist only for the
    NRMSE trace.  Returns ``(data', {"Xh", "Xl"})`` so the dictionary
    update still reaches the broadcast carry every iteration
    (``light_updates_replicated`` in ``core.engine.make_scan_step``)."""

    def step(d, rep, axes):
        return _iterate(d, rep, axes, cfg)

    return step


def make_cost_fn(cfg: SCDLConfig):
    """Standalone NRMSE objective over the post-iteration state — the
    per-chunk cost mode (``core.engine.make_chunk_cost_step``).  The
    refreshed broadcast carry holds the iteration's dictionaries, so
    this computes exactly the numbers the full step would have logged
    for the chunk's final iteration."""

    def cost(d, rep, axes):
        res_h = jnp.sum((d["Sh"] - d["Wh"] @ rep["Xh"].T) ** 2)
        res_l = jnp.sum((d["Sl"] - d["Wl"] @ rep["Xl"].T) ** 2)
        parts = {"res_h": res_h, "res_l": res_l}
        if axes:
            parts = jax.tree.map(lambda x: jax.lax.psum(x, axes), parts)
        nrmse_h = jnp.sqrt(parts["res_h"] / (rep["n_h"] + 1e-12))
        nrmse_l = jnp.sqrt(parts["res_l"] / (rep["n_l"] + 1e-12))
        return {"cost": 0.5 * (nrmse_h + nrmse_l),
                "nrmse_h": nrmse_h, "nrmse_l": nrmse_l}

    return cost


def make_refresh_fn(cfg: SCDLConfig):
    """Step 7's per-iteration broadcast: fold the reduced dictionary
    update back into the replicated state AND post-process it into the
    factor-once solve operators (Gram/companion build + Cholesky +
    ``cho_solve``).  Runs inside the fused scan carry
    (``core.engine.make_scan_step``), so neither the dictionaries nor
    their factors ever leave the device between iterations."""

    def refresh(rep, out):
        return dict(rep, **broadcast_factors(out["Xh"], out["Xl"], cfg))

    return refresh


@register("scdl")
class SCDLProblem(Problem):
    """Algorithm 2, declared once (DESIGN.md §14).

    The dictionaries (and their factor-once solve operators) are part of
    the iterate, not of the objective — ``replicated_in_carry`` makes
    the derived wiring advance the broadcast state on *every* iteration
    (``light_updates_replicated``), and the declared ``cost`` enables
    the per-chunk objective mode ``cost_every="chunk"``.
    """

    replicated_in_carry = True

    def __init__(self, cfg: Optional[SCDLConfig] = None, key=None):
        self.cfg = cfg if cfg is not None else SCDLConfig()
        self.key = key
        self._step = make_step_fn(self.cfg)
        self._light = make_light_step_fn(self.cfg)
        self._cost = make_cost_fn(self.cfg)
        self._refresh = make_refresh_fn(self.cfg)

    def init_bundle(self, inputs, mesh) -> Bundle:
        S_h, S_l = inputs
        return build_bundle(S_h, S_l, self.cfg, mesh=mesh, key=self.key)

    def full_step(self, d, rep, axes):
        return self._step(d, rep, axes)

    def light_step(self, d, rep, axes):
        return self._light(d, rep, axes)

    def cost(self, d, rep, axes):
        return self._cost(d, rep, axes)

    def refresh_replicated(self, rep, out):
        return self._refresh(rep, out)

    def finalize(self, bundle, log):
        Xh = jax.device_get(bundle.replicated["Xh"])
        Xl = jax.device_get(bundle.replicated["Xl"])
        return (Xh, Xl), {}

    def batch_axes(self):
        from repro.core.batching import BatchAxes
        # samples live on axis 1 of the raw (P, K)/(M, K) patch
        # matrices.  No record padding: the per-iteration Gram matrices
        # reduce over the sample axis, and although zero columns add
        # nothing analytically, the dictionaries are part of the carry
        # and sensitive to the reduction's floating-point grouping —
        # instances bucket on exact K instead.  The dictionaries and
        # their factor caches are per-instance iterate state, so
        # nothing is shared across a bucket.
        return BatchAxes(record_axes=(1, 1), pad_records=False,
                        instance_invariant=("key",))


def train(S_h, S_l, cfg: SCDLConfig, mesh=None, key=None,
          max_iter: Optional[int] = None, chunk: int = 8,
          cost_every=1):
    """End-to-end Algorithm 2. Returns (X_h*, X_l*, log).

    ``cost_every=k`` evaluates the NRMSE objective every k-th iteration
    only (the iterates are unaffected; off-grid log entries carry the
    last evaluated value forward, DESIGN.md §12).  ``cost_every="chunk"``
    is the fastest observability mode: one objective evaluation per
    dispatched chunk, on its final state — the granularity the driver
    checks convergence at anyway (DESIGN.md §13).

    .. deprecated:: PR 4
        Thin shim over ``solve(SCDLProblem(cfg, key), S_h, S_l)``
        (bit-identical wiring); use the ``solve()`` entry point.
    """
    warnings.warn(
        "scdl.train(...) is deprecated; use repro.core.problem.solve("
        '"scdl", S_h, S_l, cfg=cfg, ...) (DESIGN.md §14)',
        DeprecationWarning, stacklevel=2)
    sol = solve(SCDLProblem(cfg, key=key), S_h, S_l, mesh=mesh,
                max_iter=max_iter, chunk=chunk, cost_every=cost_every)
    Xh, Xl = sol.x
    return Xh, Xl, sol.log
