"""Algorithm 2 — Sparse Coupled Dictionary Learning over the bundle.

ADMM for Eq. (4): recover coupled low/high-resolution dictionaries
X_l, X_h and shared sparse codes from paired observations S_l, S_h.

Distribution (mirrors the paper's pseudo-code):
  1.   parallelise S_h, S_l over samples (K axis)        -> Bundle.create
  2/3. initialise dictionaries from random bundle samples -> init_dicts
  4/5. zip + enrich with W_h, W_l, P, Q, Y1, Y2, Y3       -> same bundle
  6-10. per iteration:
     7. broadcast X_h, X_l (+ precomputed (2X^T X + (c+c3)I)^-1)
        -> replicated side of the bundle
     8. map: local W/P/Q/Y updates on each sample block
     9. map-reduce: psum outer products S W^T (P x A), W W^T (A x A)
        -> the all-reduce that replaces the paper's reduce-to-driver
    10. replicated dictionary update (Eq. 6-7) + column norm clipping

The sequential reference is the same step with an unpartitioned bundle —
used by tests to assert distributed == sequential math.

Deviation note (DESIGN.md §9): the paper's Eq. (6-7) write the dictionary
update as X += S W^T/(phi + delta); we implement the standard damped
least-squares solve X = (S W^T)(phi + delta I)^-1 that this abbreviates
(Fotiadou et al.'s Alg. 1), with unit-norm column clipping per Eq. (4).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.bundle import Bundle, bundle_map_reduce, gather
from repro.core.driver import IterativeDriver


@dataclass(frozen=True)
class SCDLConfig:
    n_atoms: int = 512             # A
    lam_h: float = 0.01
    lam_l: float = 0.01
    c1: float = 0.4
    c2: float = 0.4
    c3: float = 0.8
    delta: float = 1e-2
    max_iter: int = 100
    tol: float = 0.0               # paper runs to i_max


def init_dicts(S_h, S_l, cfg: SCDLConfig, key=None):
    """Steps 2/3: random sample columns -> initial unit-norm dictionaries."""
    key = key if key is not None else jax.random.PRNGKey(3)
    K = S_h.shape[1]
    idx = jax.random.choice(key, K, (cfg.n_atoms,), replace=False)
    X_h = S_h[:, idx]
    X_l = S_l[:, idx]
    X_h = X_h / jnp.maximum(jnp.linalg.norm(X_h, axis=0, keepdims=True), 1e-8)
    X_l = X_l / jnp.maximum(jnp.linalg.norm(X_l, axis=0, keepdims=True), 1e-8)
    return X_h, X_l


def build_bundle(S_h, S_l, cfg: SCDLConfig, mesh=None, key=None
                 ) -> Bundle:
    """Steps 1-5: sample-axis bundle; record axis = K (transposed blocks)."""
    X_h, X_l = init_dicts(S_h, S_l, cfg, key)
    A = cfg.n_atoms
    K = S_h.shape[1]
    zeros = lambda: jnp.zeros((K, A), S_h.dtype)
    data = {
        "Sh": S_h.T, "Sl": S_l.T,              # (K, P) / (K, M)
        "Wh": zeros(), "Wl": zeros(),          # (K, A) sample-major codes
        "P": zeros(), "Q": zeros(),
        "Y1": zeros(), "Y2": zeros(), "Y3": zeros(),
    }
    replicated = {"Xh": X_h, "Xl": X_l}
    return Bundle.create(data, mesh=mesh, replicated=replicated)


def _code_updates(d, rep, cfg: SCDLConfig):
    """Step 8: local ADMM updates for one sample block (all (K_loc, .))."""
    Xh, Xl = rep["Xh"], rep["Xl"]
    c1, c2, c3 = cfg.c1, cfg.c2, cfg.c3
    A = Xh.shape[1]
    eye = jnp.eye(A, dtype=Xh.dtype)

    # W solves (ridge systems with the broadcast dictionaries)
    Gh = 2.0 * Xh.T @ Xh + (c1 + c3) * eye
    Gl = 2.0 * Xl.T @ Xl + (c2 + c3) * eye
    rhs_h = (2.0 * d["Sh"] @ Xh + c1 * d["P"] + d["Y1"]
             - d["Y3"] + c3 * d["Wl"])
    Wh = jnp.linalg.solve(Gh, rhs_h.T).T
    rhs_l = (2.0 * d["Sl"] @ Xl + c2 * d["Q"] + d["Y2"]
             + d["Y3"] + c3 * Wh)
    Wl = jnp.linalg.solve(Gl, rhs_l.T).T

    soft = lambda x, t: jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)
    P = soft(Wh - d["Y1"] / c1, cfg.lam_h / c1)
    Q = soft(Wl - d["Y2"] / c2, cfg.lam_l / c2)
    Y1 = d["Y1"] + c1 * (P - Wh)
    Y2 = d["Y2"] + c2 * (Q - Wl)
    Y3 = d["Y3"] + c3 * (Wh - Wl)
    return dict(d, Wh=Wh, Wl=Wl, P=P, Q=Q, Y1=Y1, Y2=Y2, Y3=Y3)


def _outer_products(d, axes):
    """Step 9: psum-reduced S W^T and W W^T (the paper's map-reduce)."""
    parts = {
        "ShWh": d["Sh"].T @ d["Wh"],          # (P, A)
        "SlWl": d["Sl"].T @ d["Wl"],          # (M, A)
        "phi_h": d["Wh"].T @ d["Wh"],         # (A, A)
        "phi_l": d["Wl"].T @ d["Wl"],
    }
    if axes:
        parts = jax.tree.map(lambda x: jax.lax.psum(x, axes), parts)
    return parts


def _dict_update(rep, outer, cfg: SCDLConfig):
    """Step 10 / Eq. (6-7): damped LS dictionary update + column norms."""
    A = rep["Xh"].shape[1]
    eye = jnp.eye(A, dtype=rep["Xh"].dtype)
    Xh = jnp.linalg.solve(outer["phi_h"] + cfg.delta * eye,
                          outer["ShWh"].T).T
    Xl = jnp.linalg.solve(outer["phi_l"] + cfg.delta * eye,
                          outer["SlWl"].T).T
    clip = lambda X: X / jnp.maximum(
        jnp.linalg.norm(X, axis=0, keepdims=True), 1.0)
    return {"Xh": clip(Xh), "Xl": clip(Xl)}


def make_step_fn(cfg: SCDLConfig):
    """One full ADMM iteration (steps 7-10) as a bundle step.

    Returns (new_data, {"cost", "Xh", "Xl"}): the dictionaries ride in the
    reduced output (replicated), feeding the next iteration's broadcast —
    the driver swaps them into the replicated side.
    """

    def step(d, rep, axes):
        d = _code_updates(d, rep, cfg)
        outer = _outer_products(d, axes)
        new_dicts = _dict_update(rep, outer, cfg)
        # augmented-Lagrangian data terms (the paper's Fig. 14 metric is
        # the reconstruction error of the *calculated dictionaries*)
        res_h = jnp.sum((d["Sh"] - d["Wh"] @ new_dicts["Xh"].T) ** 2)
        res_l = jnp.sum((d["Sl"] - d["Wl"] @ new_dicts["Xl"].T) ** 2)
        n_h = jnp.sum(d["Sh"] ** 2)
        n_l = jnp.sum(d["Sl"] ** 2)
        parts = {"res_h": res_h, "res_l": res_l, "n_h": n_h, "n_l": n_l}
        if axes:
            parts = jax.tree.map(lambda x: jax.lax.psum(x, axes), parts)
        nrmse_h = jnp.sqrt(parts["res_h"] / (parts["n_h"] + 1e-12))
        nrmse_l = jnp.sqrt(parts["res_l"] / (parts["n_l"] + 1e-12))
        out = {"cost": 0.5 * (nrmse_h + nrmse_l),
               "nrmse_h": nrmse_h, "nrmse_l": nrmse_l, **new_dicts}
        return d, out

    return step


def refresh_dicts(rep, out):
    """Step 7's per-iteration broadcast: fold the reduced dictionary
    update back into the replicated state.  Runs inside the fused scan
    carry (``core.engine.make_scan_step``), so the dictionaries never
    leave the device between iterations."""
    return {"Xh": out["Xh"], "Xl": out["Xl"]}


def train(S_h, S_l, cfg: SCDLConfig, mesh=None, key=None,
          max_iter: Optional[int] = None, chunk: int = 8):
    """End-to-end Algorithm 2. Returns (X_h*, X_l*, log)."""
    bundle = build_bundle(S_h, S_l, cfg, mesh=mesh, key=key)
    driver = IterativeDriver(make_step_fn(cfg), bundle,
                             max_iter=max_iter or cfg.max_iter,
                             tol=cfg.tol, chunk=chunk,
                             update_replicated=refresh_dicts)
    out = driver.run()
    Xh = jax.device_get(out.replicated["Xh"])
    Xl = jax.device_get(out.replicated["Xl"])
    return Xh, Xl, driver.log
