"""Condat primal-dual splitting for space-variant deconvolution (Eq. 2/3).

Sequential reference implementation, written so the *identical* per-record
update functions are reused by the distributed Algorithm-1 driver in
``deconvolve.py`` — the paper's re-usability argument: the RDD
Bundle/Unbundle components keep the core algorithm intact.

  sparse  : min_X  0.5||Y - H(X)||_F^2 + ||W o Phi(X)||_1   s.t. X >= 0
  lowrank : min_X  0.5||Y - H(X)||_F^2 + lam ||X||_*        s.t. X >= 0

Condat (2013) iterations with f = data term, g = positivity indicator,
h o L the regulariser (L = Phi for sparse, L = I for low-rank).

Hot-path structure (DESIGN.md §16): the PSF kernel spectra are computed
once as the (kf, conj kf) pair on the derived fast pad
(``psf.psf_fft_pair``) and H(X) is carried across iterations, so each
iteration runs exactly one forward and one adjoint spectral multiply;
Phi/Phi^T run through the batched starlet kernel, with Phi(X) carried
so the over-relaxed dual input is the linear combination
Phi(2 X_new - X) = 2 Phi(X_new) - Phi(X) — ONE starlet forward per
iteration, shared between the dual clamp and the objective; the
elementwise tails run through the fused ``kernels/condat_elwise``
passes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.imaging import lowrank as lr
from repro.imaging import psf as psf_op
from repro.imaging import starlet
from repro.kernels.condat_elwise.ops import condat_dual, condat_primal
from repro.kernels.starlet2d import ops as starlet_batch


@dataclass(frozen=True)
class SolverConfig:
    mode: str = "sparse"            # sparse | lowrank
    n_scales: int = 4
    lam: float = 0.1                # low-rank threshold
    k_sigma: float = 3.0            # sparse threshold in noise sigmas
    tau: float = 0.0                # 0 -> derived from operator norms
    sigma_dual: float = 0.0
    rank: int = 32                  # randomized-SVT rank (distributed)
    max_iter: int = 300
    tol: float = 1e-4


class SolverState(NamedTuple):
    X: jax.Array                    # primal    (n, S, S)
    U: jax.Array                    # dual      (sparse: (J, n, S, S); lowrank: (n, S, S))
    HX: jax.Array                   # carried H(X)        (n, S, S)
    CX: jax.Array                   # carried Phi(X)      (J, n, S, S); () in lowrank
    cost: jax.Array                 # scalar


# ---------------------------------------------------------------------
# Per-record pieces (pure; used verbatim by the distributed driver)
# ---------------------------------------------------------------------

def grad_data(X, Y, psfs):
    """grad of 0.5||Y - H(X)||^2 = H^T(H(X) - Y)."""
    return psf_op.Ht(psf_op.H(X, psfs) - Y, psfs)


def grad_from_HX(HX, Y, kf_pair):
    """Same gradient with H(X) carried from the previous iteration and
    the conjugate PSF spectrum precomputed in the carried pair: one
    rfft2 -> multiply -> irfft2, no kernel FFT, no conjugation."""
    return psf_op.Ht_fp(HX - Y, kf_pair)


def data_cost_from(HX, Y):
    """0.5||Y - H(X)||_F^2 off the carried forward model — free."""
    return 0.5 * jnp.sum((Y - HX) ** 2)


def weight_matrix(psfs, sigma: float, n_scales: int, k_sigma: float):
    """W^(k): per-scale noise-adaptive thresholds, shaped like Phi(X).

    The noise in scale j of H^T-filtered data scales with the per-scale
    amplification of the starlet AND the PSF energy; following Farrens et
    al. we calibrate by propagating the PSF through the transform.
    """
    scale_std = starlet.noise_std_scales(n_scales)            # (J,)
    psf_energy = jnp.sqrt(jnp.sum(psfs ** 2, axis=(-2, -1)))  # (n,)
    w = (k_sigma * sigma) * scale_std[:, None] * psf_energy[None, :]
    return w[:, :, None, None]                                # (J, n, 1, 1)


def sparse_dual_update(U, CX_new, CX, W, sig):
    """prox of the conjugate of ||W o .||_1 at the over-relaxed point:
    clamp U + sig Phi(X_bar) to [-W, W], with Phi(X_bar) formed as the
    linear combination 2 CX_new - CX of the carried starlet stacks —
    one fused elementwise pass (``kernels/condat_elwise``), no second
    transform, no X_bar materialisation."""
    return condat_dual(U, CX_new, CX, W, sig)


def sparse_dual_adjoint(U, n_scales):
    """Batched Phi^T over the dual stack: (J, n, S, S) -> (n, S, S)."""
    return starlet_batch.adjoint(U, n_scales)


def primal_update(X, U_adj, grad, tau):
    """Fused gradient step + positivity prox (one elementwise pass)."""
    return condat_primal(X, U_adj, grad, tau)


def data_cost(X, Y, psfs):
    return 0.5 * jnp.sum((Y - psf_op.H(X, psfs)) ** 2)


def sparse_reg_cost(CX, W):
    """||W o Phi(X)||_1 off the carried coefficient stack — the starlet
    forward already ran for the dual update, so the objective is a
    weighted reduction, not a second transform."""
    return jnp.sum(jnp.abs(W * CX))


# ---------------------------------------------------------------------
# Sequential solver (the github.com/sfarrens/psf counterpart)
# ---------------------------------------------------------------------

def step_sizes(Y, psfs, cfg: SolverConfig, sigma_noise: float,
               kf_pair=None):
    """Condat step sizes from operator norms: 1/tau - sig*||L||^2 >= b/2.

    ``kf_pair`` (``psf.psf_fft_pair``) is threaded into the spectral
    power iteration so the PSF stack is FFT'd exactly once per solve."""
    norm_H = psf_op.spectral_norm(psfs, kf_pair=kf_pair)
    if cfg.mode == "sparse":
        norm_L = starlet.spectral_norm(cfg.n_scales, Y.shape[-2:])
        W = weight_matrix(psfs, sigma_noise, cfg.n_scales, cfg.k_sigma)
    else:
        norm_L, W = 1.0, None
    sig = cfg.sigma_dual or 0.5 / max(norm_L ** 2, 1e-12)
    tau = cfg.tau or 1.0 / (norm_H ** 2 / 2 + sig * norm_L ** 2 + 1e-12)
    return tau, sig, W


def solve(Y, psfs, cfg: SolverConfig, sigma_noise: float = 0.02,
          n_iter: Optional[int] = None, cost_every: int = 1):
    """Run the solver; returns (X*, cost history (max_iter,)).

    ``cost_every``: evaluate the objective (a weighted reduction of the
    carried starlet stack in sparse mode, an SVD in low-rank mode) only
    every k-th iteration; skipped entries of the history carry the last
    evaluated value forward.
    """
    n_iter = n_iter or cfg.max_iter
    cost_every = max(int(cost_every), 1)
    kf_pair = psf_op.psf_fft_pair(psfs)
    tau, sig, W = step_sizes(Y, psfs, cfg, sigma_noise, kf_pair=kf_pair)
    X0 = psf_op.Ht_fp(Y, kf_pair)
    HX0 = psf_op.H_fp(X0, kf_pair)
    if cfg.mode == "sparse":
        U0 = jnp.zeros((cfg.n_scales, Y.shape[0]) + Y.shape[1:])
        CX0 = starlet_batch.forward(X0, cfg.n_scales)
    else:
        U0 = jnp.zeros_like(Y)
        CX0 = jnp.zeros(())

    def step(state: SolverState, i):
        X, U = state.X, state.U
        if cfg.mode == "sparse":
            U_adj = sparse_dual_adjoint(U, cfg.n_scales)
        else:
            U_adj = U
        grad = grad_from_HX(state.HX, Y, kf_pair)
        if cfg.mode == "sparse":
            X_new = primal_update(X, U_adj, grad, tau)
            CX_new = starlet_batch.forward(X_new, cfg.n_scales)
            U_new = sparse_dual_update(U, CX_new, state.CX, W, sig)
            HX_new = psf_op.H_fp(X_new, kf_pair)

            def eval_cost():
                return data_cost_from(HX_new, Y) + \
                    sparse_reg_cost(CX_new, W)
        else:
            X_new, X_bar = condat_primal(X, U_adj, grad, tau,
                                         with_xbar=True)
            CX_new = state.CX
            V = U + sig * X_bar
            flat = (V / sig).reshape(V.shape[0], -1)
            U_new = V - sig * lr.svt(flat, cfg.lam / sig).reshape(V.shape)
            HX_new = psf_op.H_fp(X_new, kf_pair)

            def eval_cost():
                s = jnp.linalg.svd(X_new.reshape(X_new.shape[0], -1),
                                   compute_uv=False)
                return data_cost_from(HX_new, Y) + cfg.lam * jnp.sum(s)
        if cost_every > 1:
            cost = jax.lax.cond(i % cost_every == 0, eval_cost,
                                lambda: state.cost)
        else:
            cost = eval_cost()
        new = SolverState(X=X_new, U=U_new, HX=HX_new, CX=CX_new,
                          cost=cost)
        return new, cost

    init = SolverState(X=X0, U=U0, HX=HX0, CX=CX0,
                       cost=jnp.float32(jnp.inf))
    final, costs = jax.lax.scan(step, init, jnp.arange(n_iter))
    return final.X, costs
