"""Isotropic undecimated wavelet transform (starlet / a-trous B3-spline).

This is the dictionary Phi of the paper's sparsity-regularised
deconvolution (Eq. 2): galaxy images are sparse in starlet scales.
Reference implementation in pure jnp (the Pallas kernel in
``repro.kernels.starlet2d`` tiles the same 5-tap separable cascade).

Boundary handling is periodic ('wrap'), which makes each smoothing
operator exactly self-adjoint — the adjoint cascade below then satisfies
the dot-product test to machine precision (property-tested).  iSAP uses
mirror boundaries; for 41x41 stamps whose galaxies sit well inside the
stamp the difference is negligible (documented deviation).
"""
from __future__ import annotations

import threading
from functools import lru_cache, partial

import jax
import jax.numpy as jnp

# B3-spline scaling kernel
_K = jnp.array([1.0, 4.0, 6.0, 4.0, 1.0]) / 16.0

# serializes cold misses of the memoized default-key spectral norm so
# concurrent serve workers never duplicate the 30-step power iteration
_DEFAULT_NORM_LOCK = threading.Lock()


def _smooth_axis(img: jax.Array, axis: int, step: int) -> jax.Array:
    """5-tap a-trous convolution along ``axis`` with hole size ``step``."""
    out = _K[2] * img
    for t, off in ((0, -2), (1, -1), (3, 1), (4, 2)):
        out = out + _K[t] * jnp.roll(img, off * step, axis=axis)
    return out


def smooth(img: jax.Array, scale: int) -> jax.Array:
    """One B3 smoothing at dyadic scale (2D, last two axes)."""
    step = 1 << scale
    return _smooth_axis(_smooth_axis(img, -1, step), -2, step)


def decompose(img: jax.Array, n_scales: int) -> jax.Array:
    """Starlet analysis: (..., H, W) -> (n_scales+1, ..., H, W).

    Output[0:n_scales] are detail scales, output[-1] is the coarse scale.
    Perfect reconstruction: sum over axis 0 == input (exactly).
    """
    scales = []
    c = img
    for j in range(n_scales):
        c_next = smooth(c, j)
        scales.append(c - c_next)
        c = c_next
    scales.append(c)
    return jnp.stack(scales)


def recompose(coeffs: jax.Array) -> jax.Array:
    """Inverse of :func:`decompose` (sum of scales + coarse)."""
    return jnp.sum(coeffs, axis=0)


def forward(img: jax.Array, n_scales: int) -> jax.Array:
    """Phi: detail scales only (the paper drops the coarse scale)."""
    return decompose(img, n_scales)[:-1]


def adjoint(coeffs: jax.Array, n_scales: int) -> jax.Array:
    """Phi^T for :func:`forward` (exact, by the cascade transpose).

    forward_j = (prod_{i<j} H_i)(I - H_j), all H_i self-adjoint under
    periodic boundaries, so adjoint_j = (I - H_j)(prod_{i<j} H_i) applied
    in reverse order of composition.

    Evaluated Horner-style: with v_j = (I - H_j) w_j,

        Phi^T w = v_0 + H_0 (v_1 + H_1 (v_2 + ... H_{J-2} v_{J-1}))

    which shares the cumulative smoothing products across scales —
    2J - 1 smoothing passes instead of the naive J(J+1)/2.
    """
    acc = coeffs[n_scales - 1] - smooth(coeffs[n_scales - 1], n_scales - 1)
    for j in range(n_scales - 2, -1, -1):
        v = coeffs[j] - smooth(coeffs[j], j)
        acc = v + smooth(acc, j)
    return acc


def spectral_norm(n_scales: int, shape=(41, 41), iters: int = 30,
                  key=None) -> float:
    """||Phi||_2 via power iteration (used for Condat step sizes).

    The operator depends only on ``(n_scales, shape)`` — not on any
    data — so the default-key estimate is memoized: a population of
    same-shape instances (``solve_many``, or a loop of ``solve`` calls)
    pays the 30-step iteration once, not per instance.

    Serving workers (``repro.serve``) hit this from concurrent threads.
    ``lru_cache`` itself is safe (its dict updates hold the GIL, and the
    computation is deterministic, so a duplicate-miss race would still
    be value-idempotent) — but each racing miss would trace and run the
    full 30-step power iteration, exactly the per-instance setup cost
    the memoization exists to kill, so cold misses are serialized.
    """
    if key is None:
        with _DEFAULT_NORM_LOCK:
            return _spectral_norm_default(int(n_scales), tuple(shape),
                                          int(iters))
    return _spectral_norm_impl(n_scales, shape, iters, key)


def _spectral_norm_impl(n_scales, shape, iters, key) -> float:
    x = jax.random.normal(key, shape)

    def body(x, _):
        y = forward(x, n_scales)
        x2 = adjoint(y, n_scales)
        nrm = jnp.linalg.norm(x2)
        return x2 / (nrm + 1e-12), nrm

    _, norms = jax.lax.scan(body, x, None, length=iters)
    return float(jnp.sqrt(norms[-1]))


@lru_cache(maxsize=None)
def _spectral_norm_default(n_scales: int, shape: tuple,
                           iters: int) -> float:
    return _spectral_norm_impl(n_scales, shape, iters,
                               jax.random.PRNGKey(0))


def noise_std_scales(n_scales: int, shape=(41, 41), n_mc: int = 8,
                     key=None) -> jax.Array:
    """Per-scale noise amplification factors (for the weight matrix W^(k)):
    std of each detail scale under unit white noise, Monte-Carlo estimated
    (matches iSAP's simulated-noise calibration)."""
    key = key if key is not None else jax.random.PRNGKey(1)
    noise = jax.random.normal(key, (n_mc,) + shape)
    coeffs = jax.vmap(partial(forward, n_scales=n_scales))(noise)
    return jnp.std(coeffs, axis=(0, 2, 3))
