"""Algorithm 1 — distributed space-variant PSF deconvolution.

Mirrors the paper's pseudo-code line by line:

  1. initialise X_p, X_d; extract H            -> simulate/Ht warm start
  2. parallelise Y, PSF, X_p, X_d into RDDs    -> Bundle.create
  3. sparse: map PSF -> W^(k)                  -> weight blocks in bundle
  4/5. zip into the bundled RDD D              -> one pytree, co-sharded
  6-11. iterate: map(update), map-reduce(cost) -> ONE shard_map step with
        a psum for the cost (and, for low-rank, two psums inside the
        distributed randomized SVT — the beyond-paper replacement for the
        paper's gather-to-driver SVD, DESIGN.md §2)
  12. save D / return X_p*                     -> gather()

The per-record math is imported from ``condat`` unchanged — the paper's
re-usability property of the Bundle/Unbundle design.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.bundle import Bundle, gather
from repro.core.driver import IterativeDriver
from repro.imaging import lowrank as lr
from repro.imaging import psf as psf_op
from repro.imaging import starlet
from repro.imaging.condat import (SolverConfig, data_cost, grad_data,
                                  primal_update, sparse_dual_adjoint,
                                  sparse_dual_update, sparse_reg_cost,
                                  step_sizes)


def build_bundle(Y, psfs, cfg: SolverConfig, mesh=None,
                 sigma_noise: float = 0.02) -> Tuple[Bundle, dict]:
    """Steps 1-5: parallelise + zip the inputs into the bundled RDD."""
    tau, sig, W = step_sizes(Y, psfs, cfg, sigma_noise)
    X0 = psf_op.Ht(Y, psfs)
    data = {"Y": Y, "psf": psfs, "Xp": X0}
    if cfg.mode == "sparse":
        # step 3: the weighting blocks are a *map over the PSF blocks*;
        # stored record-major (n, J, 1, 1) so they co-partition with Y.
        data["W"] = jnp.swapaxes(W, 0, 1)
        data["Xd"] = jnp.zeros((Y.shape[0], cfg.n_scales) + Y.shape[1:])
    else:
        data["Xd"] = jnp.zeros_like(Y)
    replicated = {"tau": jnp.float32(tau), "sig": jnp.float32(sig)}
    if cfg.mode == "lowrank":
        replicated["omega"] = lr.make_test_matrix(
            Y.shape[-1] * Y.shape[-2], cfg.rank)
    bundle = Bundle.create(data, mesh=mesh, replicated=replicated)
    return bundle, {"tau": tau, "sig": sig}


def make_step_fn(cfg: SolverConfig):
    """The per-partition iteration (steps 7-9): identical math to the
    sequential solver; ``axes`` carries the psum targets."""

    def step(d, rep, axes):
        Y, psfs, Xp = d["Y"], d["psf"], d["Xp"]
        tau, sig = rep["tau"], rep["sig"]
        if cfg.mode == "sparse":
            U = jnp.swapaxes(d["Xd"], 0, 1)           # (J, n_loc, S, S)
            W = jnp.swapaxes(d["W"], 0, 1)
            U_adj = sparse_dual_adjoint(U, cfg.n_scales)
            X_new = primal_update(Xp, U_adj, Y, psfs, tau)
            X_bar = 2 * X_new - Xp
            U_new = sparse_dual_update(U, X_bar, W, sig, cfg.n_scales)
            cost_part = data_cost(X_new, Y, psfs) + \
                sparse_reg_cost(X_new, W, cfg.n_scales)
            d_new = dict(d, Xp=X_new, Xd=jnp.swapaxes(U_new, 0, 1))
        else:
            U = d["Xd"]
            X_new = primal_update(Xp, U, Y, psfs, tau)
            X_bar = 2 * X_new - Xp
            V = U + sig * X_bar
            flat = (V / sig).reshape(V.shape[0], -1)
            svt_flat = lr.randomized_svt_local(
                flat, rep["omega"], cfg.lam / sig, axes=axes or None)
            U_new = V - sig * svt_flat.reshape(V.shape)
            # nuclear-norm cost via the same range finder (replicated SVD
            # of the small projected matrix)
            xf = X_new.reshape(X_new.shape[0], -1)
            y = xf @ rep["omega"]
            gram = y.T @ y
            if axes:
                gram = jax.lax.psum(gram, axes)
            s2 = jnp.linalg.eigvalsh(gram)
            nuc = jnp.sum(jnp.sqrt(jnp.maximum(s2, 0.0)))
            cost_part = data_cost(X_new, Y, psfs)
            d_new = dict(d, Xp=X_new, Xd=U_new)
            if axes:
                cost_part = jax.lax.psum(cost_part, axes)
            return d_new, {"cost": cost_part + cfg.lam * nuc}
        if axes:
            cost_part = jax.lax.psum(cost_part, axes)
        return d_new, {"cost": cost_part}

    return step


def deconvolve(Y, psfs, cfg: SolverConfig, mesh=None,
               sigma_noise: float = 0.02,
               max_iter: Optional[int] = None,
               tol: Optional[float] = None):
    """End-to-end Algorithm 1. Returns (X*, driver log)."""
    bundle, _ = build_bundle(Y, psfs, cfg, mesh=mesh,
                             sigma_noise=sigma_noise)
    driver = IterativeDriver(
        make_step_fn(cfg), bundle,
        max_iter=max_iter or cfg.max_iter, tol=tol or cfg.tol)
    out = driver.run()
    return gather(out)["Xp"], driver.log
