"""Algorithm 1 — distributed space-variant PSF deconvolution.

Mirrors the paper's pseudo-code line by line:

  1. initialise X_p, X_d; extract H            -> simulate/Ht warm start
  2. parallelise Y, PSF, X_p, X_d into RDDs    -> Bundle.create
  3. sparse: map PSF -> W^(k)                  -> weight blocks in bundle
  4/5. zip into the bundled RDD D              -> one pytree, co-sharded
  6-11. iterate: map(update), map-reduce(cost) -> ONE shard_map step with
        a psum for the cost (and, for low-rank, two psums inside the
        distributed randomized SVT — the beyond-paper replacement for the
        paper's gather-to-driver SVD, DESIGN.md §2)
  12. save D / return X_p*                     -> gather()

The per-record math is imported from ``condat`` unchanged — the paper's
re-usability property of the Bundle/Unbundle design.  The iteration loop
itself runs chunked on-device (``chunk`` iterations per dispatch,
DESIGN.md §12); ``make_light_step_fn`` is the cost-free step used to
skip the objective evaluation off the ``cost_every`` grid.

The workload is declared once as :class:`DeconvolutionProblem`
(registered under ``"deconvolve"``, DESIGN.md §14) and run through the
generic ``repro.core.problem.solve`` entry point; the original
``deconvolve(...)`` signature survives as a deprecation shim over it.
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.bundle import Bundle, gather
from repro.core.problem import Problem, register, solve
from repro.imaging import lowrank as lr
from repro.imaging import psf as psf_op
from repro.imaging import starlet
from repro.imaging.condat import (SolverConfig, data_cost_from,
                                  grad_from_HX, primal_update,
                                  sparse_dual_adjoint, sparse_dual_update,
                                  sparse_reg_cost, step_sizes)
from repro.kernels.condat_elwise.ops import condat_primal
from repro.kernels.starlet2d import ops as starlet_batch


def build_bundle(Y, psfs, cfg: SolverConfig, mesh=None,
                 sigma_noise: float = 0.02) -> Tuple[Bundle, dict]:
    """Steps 1-5: parallelise + zip the inputs into the bundled RDD.

    Beyond the paper's five arrays, the bundle carries three derived
    co-partitioned leaves that make each iteration cheaper (DESIGN.md
    §16): ``psf_fp`` (the (kf, conj kf) kernel-spectrum pair on the
    derived fast pad, constant across iterations), ``HX`` (the forward
    model of the current primal, reused by the next iteration's gradient
    so H runs once per iteration, not twice) and — sparse mode — ``CX``
    (the starlet stack Phi(X), so the over-relaxed dual input is the
    linear combination 2 Phi(X_new) - Phi(X) and one transform per
    iteration serves dual update and objective alike).
    """
    kf_pair = psf_op.psf_fft_pair(psfs)
    tau, sig, W = step_sizes(Y, psfs, cfg, sigma_noise, kf_pair=kf_pair)
    X0 = psf_op.Ht_fp(Y, kf_pair)
    data = {"Y": Y, "psf_fp": kf_pair, "Xp": X0,
            "HX": psf_op.H_fp(X0, kf_pair)}
    if cfg.mode == "sparse":
        # step 3: the weighting blocks are a *map over the PSF blocks*;
        # stored record-major (n, J, 1, 1) so they co-partition with Y.
        data["W"] = jnp.swapaxes(W, 0, 1)
        data["Xd"] = jnp.zeros((Y.shape[0], cfg.n_scales) + Y.shape[1:])
        data["CX"] = jnp.swapaxes(
            starlet_batch.forward(X0, cfg.n_scales), 0, 1)
    else:
        data["Xd"] = jnp.zeros_like(Y)
    replicated = {"tau": jnp.float32(tau), "sig": jnp.float32(sig)}
    if cfg.mode == "lowrank":
        replicated["omega"] = lr.make_test_matrix(
            Y.shape[-1] * Y.shape[-2], cfg.rank)
    bundle = Bundle.create(data, mesh=mesh, replicated=replicated)
    return bundle, {"tau": tau, "sig": sig}


def _sparse_update(d, rep, cfg: SolverConfig):
    """Steps 7-8 (sparse): primal + dual updates, no cost.  Returns the
    new data blocks plus the scale-major (W, CX_new) the objective
    reuses — the iteration's single starlet forward serves both."""
    U = jnp.swapaxes(d["Xd"], 0, 1)               # (J, n_loc, S, S)
    W = jnp.swapaxes(d["W"], 0, 1)
    CX = jnp.swapaxes(d["CX"], 0, 1)
    U_adj = sparse_dual_adjoint(U, cfg.n_scales)
    grad = grad_from_HX(d["HX"], d["Y"], d["psf_fp"])
    X_new = primal_update(d["Xp"], U_adj, grad, rep["tau"])
    CX_new = starlet_batch.forward(X_new, cfg.n_scales)
    U_new = sparse_dual_update(U, CX_new, CX, W, rep["sig"])
    return dict(d, Xp=X_new, Xd=jnp.swapaxes(U_new, 0, 1),
                CX=jnp.swapaxes(CX_new, 0, 1),
                HX=psf_op.H_fp(X_new, d["psf_fp"])), (W, CX_new)


def _lowrank_update(d, rep, axes, cfg: SolverConfig):
    """Steps 7-8 (low-rank): primal update + distributed randomized SVT."""
    U, sig = d["Xd"], rep["sig"]
    grad = grad_from_HX(d["HX"], d["Y"], d["psf_fp"])
    X_new, X_bar = condat_primal(d["Xp"], U, grad, rep["tau"],
                                 with_xbar=True)
    V = U + sig * X_bar
    flat = (V / sig).reshape(V.shape[0], -1)
    svt_flat = lr.randomized_svt_local(
        flat, rep["omega"], cfg.lam / sig, axes=axes or None)
    U_new = V - sig * svt_flat.reshape(V.shape)
    return dict(d, Xp=X_new, Xd=U_new,
                HX=psf_op.H_fp(X_new, d["psf_fp"]))


def make_step_fn(cfg: SolverConfig):
    """The per-partition iteration (steps 7-9): identical math to the
    sequential solver; ``axes`` carries the psum targets."""

    def step(d, rep, axes):
        if cfg.mode == "sparse":
            d_new, (W, CX_new) = _sparse_update(d, rep, cfg)
            cost_part = data_cost_from(d_new["HX"], d["Y"]) + \
                sparse_reg_cost(CX_new, W)
            if axes:
                cost_part = jax.lax.psum(cost_part, axes)
            return d_new, {"cost": cost_part}
        d_new = _lowrank_update(d, rep, axes, cfg)
        # nuclear-norm cost via the same range finder
        nuc = lr.nuclear_norm_rf(
            d_new["Xp"].reshape(d_new["Xp"].shape[0], -1),
            rep["omega"], axes)
        cost_part = data_cost_from(d_new["HX"], d["Y"])
        if axes:
            cost_part = jax.lax.psum(cost_part, axes)
        return d_new, {"cost": cost_part + cfg.lam * nuc}

    return step


def make_light_step_fn(cfg: SolverConfig):
    """The same iteration without the objective evaluation — the
    ``cost_every`` fast path (skips a full starlet forward + PSF
    convolution per record in sparse mode, a Gram eigendecomposition in
    low-rank mode)."""

    def step(d, rep, axes):
        if cfg.mode == "sparse":
            d_new, _ = _sparse_update(d, rep, cfg)
            return d_new
        return _lowrank_update(d, rep, axes, cfg)

    return step


def make_cost_fn(cfg: SolverConfig):
    """Standalone objective over the post-iteration state — the
    ``cost_every="chunk"`` mode (``engine.make_chunk_cost_step``): the
    scan body runs only the cost-free step and this evaluates once per
    dispatch, off the carried forward model ``HX``."""

    def cost(d, rep, axes):
        data_part = data_cost_from(d["HX"], d["Y"])
        if cfg.mode == "sparse":
            # the carried CX IS Phi(Xp): the per-chunk objective is a
            # weighted reduction with no transform at all
            reg = sparse_reg_cost(jnp.swapaxes(d["CX"], 0, 1),
                                  jnp.swapaxes(d["W"], 0, 1))
            total = data_part + reg
            if axes:
                total = jax.lax.psum(total, axes)
            return {"cost": total}
        if axes:
            data_part = jax.lax.psum(data_part, axes)
        nuc = lr.nuclear_norm_rf(d["Xp"].reshape(d["Xp"].shape[0], -1),
                                 rep["omega"], axes)
        return {"cost": data_part + cfg.lam * nuc}

    return cost


@register("deconvolve")
class DeconvolutionProblem(Problem):
    """Algorithm 1, declared once (DESIGN.md §14).

    ``cfg.mode`` selects the regulariser: ``"sparse"`` (starlet + noise-
    adaptive weights) or ``"lowrank"`` (distributed randomized SVT).
    The broadcast state (step sizes, SVT test matrix) is constant across
    iterations, so there is no ``refresh_replicated`` and the light step
    returns bare data (``replicated_in_carry`` stays False).
    """

    def __init__(self, cfg: Optional[SolverConfig] = None,
                 sigma_noise: float = 0.02):
        self.cfg = cfg if cfg is not None else SolverConfig()
        self.sigma_noise = sigma_noise
        self._step = make_step_fn(self.cfg)
        self._light = make_light_step_fn(self.cfg)
        self._cost = make_cost_fn(self.cfg)

    def init_bundle(self, inputs, mesh) -> Bundle:
        Y, psfs = inputs
        bundle, _ = build_bundle(Y, psfs, self.cfg, mesh=mesh,
                                 sigma_noise=self.sigma_noise)
        return bundle

    def full_step(self, d, rep, axes):
        return self._step(d, rep, axes)

    def light_step(self, d, rep, axes):
        return self._light(d, rep, axes)

    def cost(self, d, rep, axes):
        return self._cost(d, rep, axes)

    def finalize(self, bundle, log):
        return gather(bundle)["Xp"], {}

    def batch_axes(self):
        from repro.core.batching import BatchAxes
        # (Y, psfs) are both stamp-major; every bundle leaf (including
        # the paired PSF spectra and the starlet weights) is fully
        # per-record, so zero-padded stamps are inert.  The SVT test
        # matrix depends only on config and is shared across a bucket;
        # the noise level is a constructor scalar shared by declaration.
        shared = ("omega",) if self.cfg.mode == "lowrank" else ()
        return BatchAxes(record_axes=(0, 0), shared_in_batch=shared,
                         instance_invariant=("sigma_noise",))


def deconvolve(Y, psfs, cfg: SolverConfig, mesh=None,
               sigma_noise: float = 0.02,
               max_iter: Optional[int] = None,
               tol: Optional[float] = None,
               chunk: int = 8, cost_every: int = 1):
    """End-to-end Algorithm 1. Returns (X*, driver log).

    .. deprecated:: PR 4
        Thin shim over ``solve(DeconvolutionProblem(cfg), Y, psfs)``
        (bit-identical wiring); use the ``solve()`` entry point.
    """
    warnings.warn(
        "deconvolve(...) is deprecated; use repro.core.problem.solve("
        '"deconvolve", Y, psfs, cfg=cfg, ...) (DESIGN.md §14)',
        DeprecationWarning, stacklevel=2)
    sol = solve(DeconvolutionProblem(cfg, sigma_noise=sigma_noise),
                Y, psfs, mesh=mesh, max_iter=max_iter,
                tol=tol, chunk=chunk, cost_every=cost_every)
    return sol.x, sol.log
