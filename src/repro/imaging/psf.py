"""Space-variant PSF forward operator H and Euclid-like data simulation.

H(X) = [H^0 x^0, ..., H^n x^n]: every galaxy stamp is convolved with the
PSF at its own sky position (object-oriented deconvolution, paper §4.1).
FFT-based valid-centred convolution on padded grids; the adjoint is
correlation (conjugate in Fourier domain) — property-tested.

Paired-FFT engine (DESIGN.md §16): the padded grid is the *smallest
fast FFT size >= 2S - 1* derived per stamp (the seed hardcoded 96 for
S = 41 — 18% stamp occupancy; the derived 81 = 3^4 cuts the FFT area
29%), the kernel spectra are carried as a precomputed ``(kf, conj kf)``
pair so the adjoint never conjugates on the hot path, and
:func:`conv_pair_f` runs one forward + one adjoint convolution of two
*independent* operands as ONE batched rfft2 -> one spectral multiply ->
one irfft2 (half the FFT launches of two separate calls).

The Great3/Euclid stamps and the 600 measured PSFs are not
redistributable offline; ``simulate`` generates matched-shape stand-ins:
Sersic-like galaxy blobs and anisotropic Gaussian PSFs whose ellipticity
varies smoothly across the field of view (the paper's "spatially varying
and anisotropic" property), plus white Gaussian noise.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

STAMP = 41


def fast_size(n: int) -> int:
    """Smallest 5-smooth integer >= n (pocketfft/XLA run radix-2/3/5
    plans; anything with a larger prime factor falls off the fast path)."""
    m = max(int(n), 1)
    while True:
        k = m
        for p in (2, 3, 5):
            while k % p == 0:
                k //= p
        if k == 1:
            return m
        m += 1


def pad_for(stamp: int, kernel: int = 0) -> int:
    """FFT grid for 'same' convolution of a (stamp, stamp) image with a
    (kernel, kernel) PSF: smallest fast size >= stamp + kernel - 1 (full
    linear-convolution support, so the cropped window is alias-free)."""
    kernel = kernel or stamp
    return fast_size(stamp + kernel - 1)


def _real(x: jax.Array) -> jax.Array:
    """FFT operand dtype: XLA's RFFT takes float32/float64 only, so
    half-precision stamps go through the engine in fp32 (results are
    cast back to the operand dtype by the callers)."""
    return x if jnp.issubdtype(x.dtype, jnp.floating) and \
        jnp.dtype(x.dtype).itemsize >= 4 else x.astype(jnp.float32)


def _fft_kernel(psf: jax.Array, pad: int) -> jax.Array:
    """Centered PSF -> rfft2 on the padded grid (kernel rolled to origin)."""
    psf = _real(psf)
    h = psf.shape[-2]
    padded = jnp.zeros(psf.shape[:-2] + (pad, pad), psf.dtype)
    padded = padded.at[..., :h, :h].set(psf)
    padded = jnp.roll(padded, (-(h // 2), -(h // 2)), axis=(-2, -1))
    return jnp.fft.rfft2(padded)


def convolve(x: jax.Array, psf: jax.Array, adjoint: bool = False
             ) -> jax.Array:
    """'same' convolution of stamps with per-stamp PSFs.

    x: (..., S, S); psf: (..., S, S) broadcast-compatible leading dims.
    One-shot convenience API — loops should precompute :func:`psf_fft`
    (or :func:`psf_fft_pair`) instead of re-FFT'ing the kernel per call.
    """
    pad = pad_for(x.shape[-1], psf.shape[-2])
    return convolve_f(x, _fft_kernel(psf, pad), adjoint)


def H(X: jax.Array, psfs: jax.Array) -> jax.Array:
    """Forward operator over a stack: (n, S, S) x (n, S, S) -> (n, S, S)."""
    return convolve(X, psfs)


def Ht(Y: jax.Array, psfs: jax.Array) -> jax.Array:
    """Adjoint of :func:`H`."""
    return convolve(Y, psfs, adjoint=True)


# --------------------------------------------- cached-kernel variants
# The PSFs are constant across solver iterations, so their padded FFTs
# (1/3 of every convolution's FFT work) are computed once and carried in
# the bundle.  The pair layout additionally bakes in the conjugate so
# the per-iteration adjoint is a plain spectral multiply.

def psf_fft(psfs: jax.Array, pad: int = 0) -> jax.Array:
    """Precompute the padded rfft2 PSF kernels for :func:`H_f`/:func:`Ht_f`."""
    return _fft_kernel(psfs, pad or pad_for(psfs.shape[-1]))


def psf_fft_pair(psfs: jax.Array, pad: int = 0) -> jax.Array:
    """The ``(kf, conj kf)`` spectra stacked record-major —
    (n, 2, pad, pad // 2 + 1) complex — so the pair co-partitions with
    the records in the bundle.  ``[:, 0]`` drives H, ``[:, 1]`` drives
    Ht (no conjugation on the hot path)."""
    kf = psf_fft(psfs, pad)
    return jnp.stack([kf, jnp.conj(kf)], axis=-3)


def grid_of(kf: jax.Array) -> int:
    """Recover the (square) padded grid size from a kernel spectrum —
    the full-height axis of rfft2 output."""
    return kf.shape[-2]


def convolve_f(x: jax.Array, kf: jax.Array, adjoint: bool = False
               ) -> jax.Array:
    """Same as :func:`convolve` with the PSF kernel FFT precomputed."""
    s = x.shape[-1]
    pad = grid_of(kf)
    xf = jnp.fft.rfft2(_real(x), s=(pad, pad))
    if adjoint:
        kf = jnp.conj(kf)
    out = jnp.fft.irfft2(xf * kf, s=(pad, pad))
    return out[..., :s, :s].astype(x.dtype)


def H_f(X: jax.Array, kf: jax.Array) -> jax.Array:
    return convolve_f(X, kf)


def Ht_f(Y: jax.Array, kf: jax.Array) -> jax.Array:
    return convolve_f(Y, kf, adjoint=True)


# ------------------------------------------------- paired convolution

def H_fp(X: jax.Array, kf_pair: jax.Array) -> jax.Array:
    """Forward convolution off the carried pair (no conj, no kernel FFT)."""
    return convolve_f(X, kf_pair[..., 0, :, :])


def Ht_fp(Y: jax.Array, kf_pair: jax.Array) -> jax.Array:
    """Adjoint convolution off the carried pair — the conjugate spectrum
    is precomputed, so this is one rfft2 -> multiply -> irfft2."""
    return convolve_f(Y, kf_pair[..., 1, :, :])


def conv_pair_f(A: jax.Array, B: jax.Array, kf_pair: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """(H(A), Ht(B)) for two independent operands in ONE batched FFT
    round trip: rfft2 of the stacked (n, 2, S, S) operand, one spectral
    multiply against the carried (kf, conj kf) pair, one irfft2 — half
    the kernel launches of calling H_f and Ht_f separately.

    Note the operands must be simultaneously available: inside the
    Condat iteration the forward input (the fresh primal) depends on the
    adjoint's output (the gradient), so the per-iteration pair there is
    a strict chain and stays two round trips (DESIGN.md §16).  Callers
    with genuinely independent operands — the augmented-operator power
    iteration in :func:`spectral_norm`, batched setup passes — get the
    full 2x launch saving.
    """
    s = A.shape[-1]
    pad = grid_of(kf_pair)
    z = jnp.stack([_real(A), _real(B)], axis=-3)     # (n, 2, S, S)
    zf = jnp.fft.rfft2(z, s=(pad, pad))
    out = jnp.fft.irfft2(zf * kf_pair, s=(pad, pad))[..., :s, :s]
    return out[..., 0, :, :].astype(A.dtype), \
        out[..., 1, :, :].astype(B.dtype)


def spectral_norm(psfs: jax.Array, iters: int = 60, key=None,
                  kf_pair: jax.Array = None) -> float:
    """||H||_2 via power iteration (the paper's solver needs it for the
    primal step size).

    Runs on the cached kernel spectra (the seed re-FFT'd the full PSF
    stack inside every iteration) and iterates the self-adjoint
    augmented operator A = [[0, Ht], [H, 0]] — A(u, v) = (Ht v, H u),
    whose spectral norm is exactly ||H||_2 — so each iteration is ONE
    :func:`conv_pair_f` round trip over two independent operands.  A
    contracts non-dominant modes at (sigma2/sigma1) per step vs the
    normal equations' square, hence the higher default ``iters`` (60
    paired round trips land a tighter estimate than the seed's 20
    normal-equation steps at half the kernel launches and none of the
    40 in-loop kernel FFTs).
    """
    if kf_pair is None:
        kf_pair = psf_fft_pair(psfs)
    key = key if key is not None else jax.random.PRNGKey(0)
    ku, kv = jax.random.split(key)
    u = jax.random.normal(ku, psfs.shape)
    v = jax.random.normal(kv, psfs.shape)
    # the whole iteration is one jitted program (module-level cache):
    # eagerly, lax.scan re-traces its closure body on every call, which
    # made this the dominant per-instance setup cost for populations.
    # Concurrent serve workers may race a cold call: jax's compilation
    # cache is internally locked, the function is pure, and its inputs
    # here are deterministic per (shape, key), so the worst case is one
    # duplicated compile, not a wrong value (regression-tested by
    # tests/test_serve.py::test_concurrent_setup_thread_safety).
    return float(_power_norm(u, v, kf_pair, iters))


@partial(jax.jit, static_argnames="iters")
def _power_norm(u, v, kf_pair, iters: int):
    nrm0 = jnp.sqrt(jnp.sum(u ** 2) + jnp.sum(v ** 2))
    u, v = u / nrm0, v / nrm0

    def body(carry, _):
        u, v = carry
        Hu, Htv = conv_pair_f(u, v, kf_pair)
        nrm = jnp.sqrt(jnp.sum(Htv ** 2) + jnp.sum(Hu ** 2)) + 1e-12
        return (Htv / nrm, Hu / nrm), nrm

    _, norms = jax.lax.scan(body, (u, v), None, length=iters)
    return norms[-1]


class PsfData(NamedTuple):
    Y: jax.Array          # noisy observed stamps   (n, S, S)
    X_true: jax.Array     # ground-truth stamps     (n, S, S)
    psfs: jax.Array       # per-object PSFs         (n, S, S)
    sigma: float          # noise std


def _gaussian2d(shape: Tuple[int, int], cx, cy, sx, sy, theta):
    yy, xx = jnp.mgrid[0:shape[0], 0:shape[1]]
    xr = (xx - cx) * jnp.cos(theta) + (yy - cy) * jnp.sin(theta)
    yr = -(xx - cx) * jnp.sin(theta) + (yy - cy) * jnp.cos(theta)
    return jnp.exp(-0.5 * ((xr / sx) ** 2 + (yr / sy) ** 2))


def simulate(n: int, key=None, stamp: int = STAMP, sigma: float = 0.02,
             dtype=jnp.float32) -> PsfData:
    """Euclid-like simulation: n stamps + spatially varying PSFs."""
    key = key if key is not None else jax.random.PRNGKey(42)
    kg, kp, kn, kpos = jax.random.split(key, 4)
    c = stamp // 2

    # galaxies: 2-component elliptical blobs with random orientation
    g1 = jax.random.uniform(kg, (n, 6))
    def galaxy(u):
        a = _gaussian2d((stamp, stamp), c + 4 * (u[0] - .5),
                        c + 4 * (u[1] - .5), 2.0 + 3.0 * u[2],
                        1.5 + 2.0 * u[3], jnp.pi * u[4])
        b = _gaussian2d((stamp, stamp), c, c, 1.0 + u[5], 1.0 + u[5], 0.0)
        img = a + 0.5 * b
        return img / jnp.sum(img)
    X = jax.vmap(galaxy)(g1).astype(dtype)

    # PSFs: anisotropy varies smoothly with a fake sky position
    pos = jax.random.uniform(kpos, (n, 2))
    def psf(p):
        e = 0.15 * jnp.sin(2 * jnp.pi * p[0]) + 0.1 * p[1]
        sx, sy = 1.8 * (1 + e), 1.8 * (1 - e)
        k = _gaussian2d((stamp, stamp), c, c, sx, sy,
                        jnp.pi * (p[0] + p[1]))
        return k / jnp.sum(k)
    psfs = jax.vmap(psf)(pos).astype(dtype)

    Y = H(X, psfs) + sigma * jax.random.normal(kn, X.shape, dtype)
    return PsfData(Y=Y, X_true=X, psfs=psfs, sigma=sigma)
