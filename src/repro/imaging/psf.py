"""Space-variant PSF forward operator H and Euclid-like data simulation.

H(X) = [H^0 x^0, ..., H^n x^n]: every galaxy stamp is convolved with the
PSF at its own sky position (object-oriented deconvolution, paper §4.1).
FFT-based valid-centred convolution on padded grids; the adjoint is
correlation (conjugate in Fourier domain) — property-tested.

The Great3/Euclid stamps and the 600 measured PSFs are not
redistributable offline; ``simulate`` generates matched-shape stand-ins:
Sersic-like galaxy blobs and anisotropic Gaussian PSFs whose ellipticity
varies smoothly across the field of view (the paper's "spatially varying
and anisotropic" property), plus white Gaussian noise.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

STAMP = 41
_PAD = 96        # >= 2*41-1, even


def _fft_kernel(psf: jax.Array, pad: int = _PAD) -> jax.Array:
    """Centered PSF -> rfft2 on the padded grid (kernel rolled to origin)."""
    h = psf.shape[-2]
    padded = jnp.zeros(psf.shape[:-2] + (pad, pad), psf.dtype)
    padded = padded.at[..., :h, :h].set(psf)
    padded = jnp.roll(padded, (-(h // 2), -(h // 2)), axis=(-2, -1))
    return jnp.fft.rfft2(padded)


def convolve(x: jax.Array, psf: jax.Array, adjoint: bool = False
             ) -> jax.Array:
    """'same' convolution of stamps with per-stamp PSFs.

    x: (..., S, S); psf: (..., S, S) broadcast-compatible leading dims.
    """
    return convolve_f(x, _fft_kernel(psf), adjoint)


def H(X: jax.Array, psfs: jax.Array) -> jax.Array:
    """Forward operator over a stack: (n, S, S) x (n, S, S) -> (n, S, S)."""
    return convolve(X, psfs)


def Ht(Y: jax.Array, psfs: jax.Array) -> jax.Array:
    """Adjoint of :func:`H`."""
    return convolve(Y, psfs, adjoint=True)


# --------------------------------------------- cached-kernel variants
# The PSFs are constant across solver iterations, so their padded FFT
# (1/3 of every convolution's FFT work) can be computed once and carried
# in the bundle — (n, PAD, PAD//2+1) complex64 per stack, ~38 KB/record.

def psf_fft(psfs: jax.Array) -> jax.Array:
    """Precompute the padded rfft2 PSF kernels for :func:`H_f`/:func:`Ht_f`."""
    return _fft_kernel(psfs)


def convolve_f(x: jax.Array, kf: jax.Array, adjoint: bool = False
               ) -> jax.Array:
    """Same as :func:`convolve` with the PSF kernel FFT precomputed."""
    s = x.shape[-1]
    xf = jnp.fft.rfft2(x, s=(_PAD, _PAD))
    if adjoint:
        kf = jnp.conj(kf)
    out = jnp.fft.irfft2(xf * kf, s=(_PAD, _PAD))
    return out[..., :s, :s]


def H_f(X: jax.Array, kf: jax.Array) -> jax.Array:
    return convolve_f(X, kf)


def Ht_f(Y: jax.Array, kf: jax.Array) -> jax.Array:
    return convolve_f(Y, kf, adjoint=True)


def spectral_norm(psfs: jax.Array, iters: int = 20, key=None) -> float:
    """||H||_2 via power iteration over the whole stack (the paper's
    solver needs it for the primal step size)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    x = jax.random.normal(key, psfs.shape)

    def body(x, _):
        y = Ht(H(x, psfs), psfs)
        nrm = jnp.linalg.norm(y)
        return y / (nrm + 1e-12), nrm

    _, norms = jax.lax.scan(body, x, None, length=iters)
    return float(jnp.sqrt(norms[-1]))


class PsfData(NamedTuple):
    Y: jax.Array          # noisy observed stamps   (n, S, S)
    X_true: jax.Array     # ground-truth stamps     (n, S, S)
    psfs: jax.Array       # per-object PSFs         (n, S, S)
    sigma: float          # noise std


def _gaussian2d(shape: Tuple[int, int], cx, cy, sx, sy, theta):
    yy, xx = jnp.mgrid[0:shape[0], 0:shape[1]]
    xr = (xx - cx) * jnp.cos(theta) + (yy - cy) * jnp.sin(theta)
    yr = -(xx - cx) * jnp.sin(theta) + (yy - cy) * jnp.cos(theta)
    return jnp.exp(-0.5 * ((xr / sx) ** 2 + (yr / sy) ** 2))


def simulate(n: int, key=None, stamp: int = STAMP, sigma: float = 0.02,
             dtype=jnp.float32) -> PsfData:
    """Euclid-like simulation: n stamps + spatially varying PSFs."""
    key = key if key is not None else jax.random.PRNGKey(42)
    kg, kp, kn, kpos = jax.random.split(key, 4)
    c = stamp // 2

    # galaxies: 2-component elliptical blobs with random orientation
    g1 = jax.random.uniform(kg, (n, 6))
    def galaxy(u):
        a = _gaussian2d((stamp, stamp), c + 4 * (u[0] - .5),
                        c + 4 * (u[1] - .5), 2.0 + 3.0 * u[2],
                        1.5 + 2.0 * u[3], jnp.pi * u[4])
        b = _gaussian2d((stamp, stamp), c, c, 1.0 + u[5], 1.0 + u[5], 0.0)
        img = a + 0.5 * b
        return img / jnp.sum(img)
    X = jax.vmap(galaxy)(g1).astype(dtype)

    # PSFs: anisotropy varies smoothly with a fake sky position
    pos = jax.random.uniform(kpos, (n, 2))
    def psf(p):
        e = 0.15 * jnp.sin(2 * jnp.pi * p[0]) + 0.1 * p[1]
        sx, sy = 1.8 * (1 + e), 1.8 * (1 - e)
        k = _gaussian2d((stamp, stamp), c, c, sx, sy,
                        jnp.pi * (p[0] + p[1]))
        return k / jnp.sum(k)
    psfs = jax.vmap(psf)(pos).astype(dtype)

    Y = H(X, psfs) + sigma * jax.random.normal(kn, X.shape, dtype)
    return PsfData(Y=Y, X_true=X, psfs=psfs, sigma=sigma)
